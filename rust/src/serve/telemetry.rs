//! Serving-plane telemetry: lock-free per-replica gauges/counters plus
//! latency histograms, aggregated into the `{"stats": true}` control
//! response.
//!
//! Replicas own the hot updates (atomic adds on their own cache line —
//! no cross-replica contention); the router reads the gauges for
//! least-loaded placement; the pool snapshots everything on demand.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::kvcache::{PrefixPool, PrefixPoolStats, TierStats};
use crate::metrics::Histogram;
use crate::util::Json;

use super::router::ReplicaRole;

/// One replica's live gauges and lifetime counters.
#[derive(Default)]
pub struct ReplicaTelemetry {
    /// Requests submitted to this replica but not yet started prefilling
    /// (bounded channel + replica-local wait queue).
    pub queued: AtomicUsize,
    /// Reserved tokens (prompt + max_new) of those queued requests.
    pub queued_tokens: AtomicUsize,
    /// Requests currently in (chunked) prefill on this replica.
    pub prefilling: AtomicUsize,
    /// Reserved tokens of the prefilling requests.
    pub prefill_tokens: AtomicUsize,
    /// Sequences live in the replica's continuous batch.
    pub live_seqs: AtomicUsize,
    /// Reserved tokens of the live sequences.
    pub live_tokens: AtomicUsize,
    /// This replica is no longer accepting new admissions (pool drain;
    /// the router skips draining replicas while alternatives exist).
    pub draining: AtomicBool,
    /// This replica is failed (engine panic caught by the supervisor, or
    /// a watchdog-detected stall): the router excludes it from placement
    /// entirely — unlike `draining` it is never a fallback target.
    pub down: AtomicBool,
    /// The supervisor is currently rebuilding this replica's Stack
    /// (between catching a panic and returning it to rotation).
    pub restarting: AtomicBool,
    /// Monotonic-clock stamp (us) of the replica engine loop's last
    /// iteration — the watchdog's liveness signal. 0 until first stamp.
    pub heartbeat_us: AtomicU64,
    /// Lifetime: times the supervisor respawned this replica's engine.
    pub restarts: AtomicU64,
    /// Lifetime: requests terminated because their deadline passed.
    pub deadline_exceeded: AtomicU64,
    /// Lifetime: fault-registry injections observed in this replica's
    /// context (chaos-test visibility; 0 in production).
    pub faults_injected: AtomicU64,
    /// Lifetime: requests admitted (prefill completed).
    pub admitted: AtomicU64,
    /// Lifetime: prefill chunks executed.
    pub prefill_chunks: AtomicU64,
    /// Lifetime: prefilled sequences handed off to another replica.
    pub handoffs_out: AtomicU64,
    /// Lifetime: sequences imported from another replica's prefill.
    pub handoffs_in: AtomicU64,
    /// Lifetime: KV payload bytes imported via handoff.
    pub handoff_bytes_in: AtomicU64,
    /// Lifetime: requests completed.
    pub finished: AtomicU64,
    /// Lifetime: requests terminated by an engine error.
    pub failed: AtomicU64,
    /// Lifetime: requests evicted because their client disconnected.
    pub cancelled: AtomicU64,
    /// Lifetime: tokens generated.
    pub tokens_out: AtomicU64,
    /// Lifetime: decode steps executed.
    pub steps: AtomicU64,
    /// Lifetime: wall time spent inside decode steps, us.
    pub busy_us: AtomicU64,
    /// Arrival -> first token, us.
    pub ttft_us: Mutex<Histogram>,
    /// Arrival -> prefill complete, us.
    pub queue_wait_us: Mutex<Histogram>,
    /// Handoff dispatch -> imported on this replica, us.
    pub handoff_us: Mutex<Histogram>,
    /// Panic caught -> replica back in rotation, us.
    pub restart_us: Mutex<Histogram>,
    /// The replica's cross-request prefix pool, registered by the
    /// replica loop when `scout.prefix_cache_blocks > 0` (None = reuse
    /// disabled). Cold path: set once at startup, read by stats
    /// snapshots and the router's locality hint.
    pub prefix_pool: Mutex<Option<Arc<PrefixPool>>>,
    /// Head-wise offload gauge: effective `scout.head_groups` of the
    /// replica's scheduler (1 = whole-layer granularity; the `headwise`
    /// stats section is `null` then, keeping the default plane
    /// byte-identical).
    pub hw_head_groups: AtomicUsize,
    /// Lifetime: (sequence, layer, group) observations where the
    /// heavy-hitter classifier held the group pinned fully GPU-resident.
    pub hw_pinned_groups: AtomicU64,
    /// Lifetime: (sequence, layer, group) observations of offloadable
    /// (non-pinned) groups.
    pub hw_offloaded_groups: AtomicU64,
    /// Lifetime: asynchronous recall traffic staged by decode steps, in
    /// bytes (group-block units times the per-group block size).
    pub hw_recall_bytes: AtomicU64,
}

impl ReplicaTelemetry {
    /// Routing load metric: reserved tokens queued + prefilling + live.
    /// Reserved (not current-KV) tokens make placement stable under
    /// decode progress.
    pub fn load_tokens(&self) -> usize {
        // ordering: monotonic gauges read for a routing heuristic — a
        // stale or torn-across-gauges read only skews placement for one
        // request; no memory is published under these counters.
        self.queued_tokens.load(Ordering::Relaxed)
            + self.prefill_tokens.load(Ordering::Relaxed)
            + self.live_tokens.load(Ordering::Relaxed)
    }

    /// Whether this replica's prefix pool currently holds the chunk
    /// with chained hash `key` — the router's prefix-locality probe.
    /// Read-only on the pool (no LRU refresh, no counter noise).
    pub fn advertises(&self, key: u64) -> bool {
        self.prefix_pool
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .as_ref()
            .is_some_and(|p| p.contains(key))
    }

    /// Prefix-pool counter snapshot, if reuse is enabled here.
    pub fn prefix_stats(&self) -> Option<PrefixPoolStats> {
        self.prefix_pool.lock().unwrap_or_else(|e| e.into_inner()).as_ref().map(|p| p.stats())
    }

    /// Lifecycle state label for snapshots: `failed` and `restarting`
    /// outrank `draining`, which outranks `ready`.
    pub fn state(&self) -> &'static str {
        // ordering: advisory state label from independent flags — a
        // transition racing the read yields the old (still truthful)
        // label, so Relaxed loads suffice.
        if self.restarting.load(Ordering::Relaxed) {
            "restarting"
        } else if self.down.load(Ordering::Relaxed) {
            "failed"
        } else if self.draining.load(Ordering::Relaxed) {
            "draining"
        } else {
            "ready"
        }
    }

    /// Requests that would sit in front of a new submission.
    pub fn depth(&self) -> usize {
        // ordering: routing heuristic like `load_tokens` — staleness is
        // benign, so Relaxed gauge reads suffice.
        self.queued.load(Ordering::Relaxed)
            + self.prefilling.load(Ordering::Relaxed)
            + self.live_seqs.load(Ordering::Relaxed)
    }

    pub fn snapshot(&self, replica: usize, role: ReplicaRole, uptime_s: f64) -> Json {
        // ordering: statistics snapshot — every load here is a Relaxed
        // read of an independently-updated gauge/counter; the snapshot is
        // not required to be a consistent cut across them.
        let tokens_out = self.tokens_out.load(Ordering::Relaxed);
        Json::obj(vec![
            ("replica", Json::num(replica as f64)),
            ("role", Json::str(role.label())),
            ("state", Json::str(self.state())),
            ("queue_depth", Json::num(self.queued.load(Ordering::Relaxed) as f64)),
            ("queued_tokens", Json::num(self.queued_tokens.load(Ordering::Relaxed) as f64)),
            ("prefilling", Json::num(self.prefilling.load(Ordering::Relaxed) as f64)),
            ("prefill_tokens", Json::num(self.prefill_tokens.load(Ordering::Relaxed) as f64)),
            ("live_seqs", Json::num(self.live_seqs.load(Ordering::Relaxed) as f64)),
            ("live_tokens", Json::num(self.live_tokens.load(Ordering::Relaxed) as f64)),
            ("admitted", Json::num(self.admitted.load(Ordering::Relaxed) as f64)),
            ("prefill_chunks", Json::num(self.prefill_chunks.load(Ordering::Relaxed) as f64)),
            ("handoffs_out", Json::num(self.handoffs_out.load(Ordering::Relaxed) as f64)),
            ("handoffs_in", Json::num(self.handoffs_in.load(Ordering::Relaxed) as f64)),
            ("handoff_bytes_in", Json::num(self.handoff_bytes_in.load(Ordering::Relaxed) as f64)),
            ("finished", Json::num(self.finished.load(Ordering::Relaxed) as f64)),
            ("failed", Json::num(self.failed.load(Ordering::Relaxed) as f64)),
            ("cancelled", Json::num(self.cancelled.load(Ordering::Relaxed) as f64)),
            ("restarts", Json::num(self.restarts.load(Ordering::Relaxed) as f64)),
            (
                "deadline_exceeded",
                Json::num(self.deadline_exceeded.load(Ordering::Relaxed) as f64),
            ),
            ("faults_injected", Json::num(self.faults_injected.load(Ordering::Relaxed) as f64)),
            ("steps", Json::num(self.steps.load(Ordering::Relaxed) as f64)),
            ("tokens_out", Json::num(tokens_out as f64)),
            (
                "tokens_per_s",
                Json::num(if uptime_s > 0.0 { tokens_out as f64 / uptime_s } else { 0.0 }),
            ),
            ("busy_us", Json::num(self.busy_us.load(Ordering::Relaxed) as f64)),
            ("ttft_us", hist_json(&self.ttft_us.lock().unwrap_or_else(|e| e.into_inner()))),
            (
                "queue_wait_us",
                hist_json(&self.queue_wait_us.lock().unwrap_or_else(|e| e.into_inner())),
            ),
            ("handoff_us", hist_json(&self.handoff_us.lock().unwrap_or_else(|e| e.into_inner()))),
            ("restart_us", hist_json(&self.restart_us.lock().unwrap_or_else(|e| e.into_inner()))),
            (
                "prefix",
                match self.prefix_stats() {
                    Some(s) => prefix_stats_json(&s),
                    None => Json::Null,
                },
            ),
            (
                "headwise",
                // ordering: statistics snapshot of independent Relaxed
                // counters, like every gauge above.
                match self.hw_head_groups.load(Ordering::Relaxed) {
                    0 | 1 => Json::Null,
                    g => Json::obj(vec![
                        ("head_groups", Json::num(g as f64)),
                        (
                            "pinned_groups",
                            Json::num(self.hw_pinned_groups.load(Ordering::Relaxed) as f64),
                        ),
                        (
                            "offloaded_groups",
                            Json::num(self.hw_offloaded_groups.load(Ordering::Relaxed) as f64),
                        ),
                        (
                            "recall_bytes",
                            Json::num(self.hw_recall_bytes.load(Ordering::Relaxed) as f64),
                        ),
                    ]),
                },
            ),
        ])
    }
}

/// Prefix-pool counters as a stats sub-object.
pub fn prefix_stats_json(s: &PrefixPoolStats) -> Json {
    Json::obj(vec![
        ("hits", Json::num(s.hits as f64)),
        ("misses", Json::num(s.misses as f64)),
        ("published", Json::num(s.published as f64)),
        ("evicted", Json::num(s.evicted as f64)),
        ("entries", Json::num(s.entries as f64)),
    ])
}

/// Session-tier counters as a stats sub-object (pool-global: one
/// [`crate::kvcache::SessionTier`] serves every replica).
pub fn tier_stats_json(s: &TierStats) -> Json {
    Json::obj(vec![
        ("sessions", Json::num(s.sessions as f64)),
        ("hot_blocks", Json::num(s.hot_blocks as f64)),
        ("dram_budget_blocks", Json::num(s.dram_budget_blocks as f64)),
        ("hot_bytes", Json::num(s.hot_bytes as f64)),
        ("cold_bytes", Json::num(s.cold_bytes as f64)),
        ("spill_file_bytes", Json::num(s.spill_file_bytes as f64)),
        ("suspended", Json::num(s.suspended as f64)),
        ("resumed", Json::num(s.resumed as f64)),
        ("spilled", Json::num(s.spilled as f64)),
        ("paged_in", Json::num(s.paged_in as f64)),
        ("shed", Json::num(s.shed as f64)),
        ("evicted", Json::num(s.evicted as f64)),
        ("misses", Json::num(s.misses as f64)),
        ("compactions", Json::num(s.compactions as f64)),
        ("page_in_us", hist_json(&s.page_in_us)),
    ])
}

/// Pool-level admission counters.
#[derive(Default)]
pub struct PoolTelemetry {
    pub submitted: AtomicU64,
    pub rejected_invalid: AtomicU64,
    pub rejected_overloaded: AtomicU64,
    pub rejected_draining: AtomicU64,
    /// Reserved in-flight tokens across the whole pool — the
    /// `token_budget` gate. Reserved atomically (`fetch_add` + check +
    /// undo) at submit so concurrent submitters cannot all slip past
    /// the cap, released by the owning replica at each request's
    /// terminal event.
    pub inflight_tokens: AtomicUsize,
}

impl PoolTelemetry {
    pub fn note_reject(&self, code: super::stream::RejectCode) {
        use super::stream::RejectCode;
        let c = match code {
            RejectCode::Invalid => &self.rejected_invalid,
            RejectCode::Overloaded => &self.rejected_overloaded,
            RejectCode::Draining => &self.rejected_draining,
        };
        // ordering: pure lifetime counter; totals are read by stats only.
        c.fetch_add(1, Ordering::Relaxed);
    }

    pub fn rejected_total(&self) -> u64 {
        // ordering: statistics read of independent Relaxed counters.
        self.rejected_invalid.load(Ordering::Relaxed)
            + self.rejected_overloaded.load(Ordering::Relaxed)
            + self.rejected_draining.load(Ordering::Relaxed)
    }
}

/// Latency-histogram summary (us).
pub fn hist_json(h: &Histogram) -> Json {
    Json::obj(vec![
        ("count", Json::num(h.count() as f64)),
        ("mean", Json::num(h.mean())),
        ("p50", Json::num(h.quantile(0.5))),
        ("p99", Json::num(h.quantile(0.99))),
        ("max", Json::num(h.max())),
    ])
}

/// Assemble the full `{"stats": true}` response body.
pub fn pool_stats_json(
    pool: &PoolTelemetry,
    replicas: &[std::sync::Arc<ReplicaTelemetry>],
    roles: &[ReplicaRole],
    uptime_s: f64,
    draining: bool,
    tier: Option<&TierStats>,
) -> Json {
    // ordering: whole-pool statistics snapshot — all atomic loads below
    // are Relaxed reads of independent gauges/counters; the report is
    // advisory and needs no consistent cut (see ReplicaTelemetry docs).
    let mut ttft = Histogram::new();
    let mut queue_wait = Histogram::new();
    let mut handoff = Histogram::new();
    let mut rows = Vec::with_capacity(replicas.len());
    let (mut depth, mut live, mut inflight, mut tokens_out) = (0usize, 0usize, 0usize, 0u64);
    let (mut cancelled, mut handoffs, mut handoff_bytes) = (0u64, 0u64, 0u64);
    let (mut restarts, mut deadline_exceeded, mut failed_replicas) = (0u64, 0u64, 0usize);
    let mut prefilling = 0usize;
    let mut prefix_agg: Option<PrefixPoolStats> = None;
    for (i, r) in replicas.iter().enumerate() {
        let role = roles.get(i).copied().unwrap_or_default();
        rows.push(r.snapshot(i, role, uptime_s));
        ttft.merge(&r.ttft_us.lock().unwrap_or_else(|e| e.into_inner()));
        queue_wait.merge(&r.queue_wait_us.lock().unwrap_or_else(|e| e.into_inner()));
        handoff.merge(&r.handoff_us.lock().unwrap_or_else(|e| e.into_inner()));
        depth += r.queued.load(Ordering::Relaxed);
        prefilling += r.prefilling.load(Ordering::Relaxed);
        live += r.live_seqs.load(Ordering::Relaxed);
        inflight += r.load_tokens();
        tokens_out += r.tokens_out.load(Ordering::Relaxed);
        cancelled += r.cancelled.load(Ordering::Relaxed);
        handoffs += r.handoffs_in.load(Ordering::Relaxed);
        handoff_bytes += r.handoff_bytes_in.load(Ordering::Relaxed);
        restarts += r.restarts.load(Ordering::Relaxed);
        deadline_exceeded += r.deadline_exceeded.load(Ordering::Relaxed);
        failed_replicas += usize::from(r.down.load(Ordering::Relaxed));
        if let Some(s) = r.prefix_stats() {
            let a = prefix_agg.get_or_insert_with(PrefixPoolStats::default);
            a.hits += s.hits;
            a.misses += s.misses;
            a.published += s.published;
            a.evicted += s.evicted;
            a.entries += s.entries;
        }
    }
    Json::obj(vec![
        ("uptime_s", Json::num(uptime_s)),
        ("draining", Json::Bool(draining)),
        // Which kernel tier produced these numbers (bench provenance).
        ("simd_level", Json::str(crate::util::simd::level().name())),
        ("replica_count", Json::num(replicas.len() as f64)),
        ("roles", Json::Arr(roles.iter().map(|r| Json::str(r.label())).collect())),
        ("submitted", Json::num(pool.submitted.load(Ordering::Relaxed) as f64)),
        ("rejected", Json::num(pool.rejected_total() as f64)),
        (
            "rejected_by",
            Json::obj(vec![
                ("invalid", Json::num(pool.rejected_invalid.load(Ordering::Relaxed) as f64)),
                (
                    "overloaded",
                    Json::num(pool.rejected_overloaded.load(Ordering::Relaxed) as f64),
                ),
                ("draining", Json::num(pool.rejected_draining.load(Ordering::Relaxed) as f64)),
            ]),
        ),
        ("cancelled", Json::num(cancelled as f64)),
        ("restarts", Json::num(restarts as f64)),
        ("deadline_exceeded", Json::num(deadline_exceeded as f64)),
        ("failed_replicas", Json::num(failed_replicas as f64)),
        ("faults_injected", Json::num(crate::util::faults::injected_total() as f64)),
        ("queue_depth", Json::num(depth as f64)),
        ("prefilling", Json::num(prefilling as f64)),
        ("live_seqs", Json::num(live as f64)),
        ("inflight_tokens", Json::num(inflight as f64)),
        ("tokens_out", Json::num(tokens_out as f64)),
        (
            "tokens_per_s",
            Json::num(if uptime_s > 0.0 { tokens_out as f64 / uptime_s } else { 0.0 }),
        ),
        ("handoffs", Json::num(handoffs as f64)),
        ("handoff_bytes", Json::num(handoff_bytes as f64)),
        ("handoff_us", hist_json(&handoff)),
        (
            "prefix",
            match &prefix_agg {
                Some(s) => prefix_stats_json(s),
                None => Json::Null,
            },
        ),
        // Null (not zeros) when the tier is disabled, matching "prefix".
        (
            "tier",
            match tier {
                Some(s) => tier_stats_json(s),
                None => Json::Null,
            },
        ),
        ("ttft_us", hist_json(&ttft)),
        ("queue_wait_us", hist_json(&queue_wait)),
        ("replicas", Json::Arr(rows)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::stream::RejectCode;
    use std::sync::Arc;

    #[test]
    fn snapshot_reports_rates_and_depths() {
        let t = ReplicaTelemetry::default();
        t.queued.store(2, Ordering::Relaxed);
        t.queued_tokens.store(64, Ordering::Relaxed);
        t.prefilling.store(1, Ordering::Relaxed);
        t.prefill_tokens.store(16, Ordering::Relaxed);
        t.live_seqs.store(1, Ordering::Relaxed);
        t.live_tokens.store(40, Ordering::Relaxed);
        t.tokens_out.store(100, Ordering::Relaxed);
        assert_eq!(t.load_tokens(), 120, "queued + prefilling + live tokens");
        assert_eq!(t.depth(), 4);
        let j = t.snapshot(0, ReplicaRole::Mixed, 2.0);
        assert_eq!(j.req_usize("queue_depth").unwrap(), 2);
        assert_eq!(j.req_usize("prefilling").unwrap(), 1);
        assert_eq!(j.req_str("role").unwrap(), "mixed");
        assert!((j.req_f64("tokens_per_s").unwrap() - 50.0).abs() < 1e-9);
    }

    #[test]
    fn pool_stats_aggregate() {
        let pool = PoolTelemetry::default();
        pool.submitted.store(5, Ordering::Relaxed);
        pool.note_reject(RejectCode::Overloaded);
        pool.note_reject(RejectCode::Invalid);
        let a = Arc::new(ReplicaTelemetry::default());
        let b = Arc::new(ReplicaTelemetry::default());
        a.tokens_out.store(30, Ordering::Relaxed);
        b.tokens_out.store(70, Ordering::Relaxed);
        a.queued.store(1, Ordering::Relaxed);
        a.handoffs_out.store(2, Ordering::Relaxed);
        b.handoffs_in.store(2, Ordering::Relaxed);
        b.handoff_bytes_in.store(4096, Ordering::Relaxed);
        b.handoff_us.lock().unwrap().record(500.0);
        a.ttft_us.lock().unwrap().record(1000.0);
        b.ttft_us.lock().unwrap().record(3000.0);
        let roles = [ReplicaRole::Prefill, ReplicaRole::Decode];
        let j = pool_stats_json(&pool, &[a, b], &roles, 1.0, false, None);
        assert_eq!(j.req_usize("rejected").unwrap(), 2);
        assert_eq!(j.req_usize("queue_depth").unwrap(), 1);
        assert_eq!(j.req_usize("tokens_out").unwrap(), 100);
        assert_eq!(j.req_usize("handoffs").unwrap(), 2);
        assert_eq!(j.req_usize("handoff_bytes").unwrap(), 4096);
        assert_eq!(j.get("handoff_us").unwrap().req_usize("count").unwrap(), 1);
        let role_labels: Vec<String> = j
            .get("roles")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_str().unwrap().to_string())
            .collect();
        assert_eq!(role_labels, vec!["prefill", "decode"]);
        let level = j.req_str("simd_level").unwrap();
        assert!(level == "portable" || level == "avx2", "{level}");
        assert_eq!(j.get("replicas").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(j.get("ttft_us").unwrap().req_usize("count").unwrap(), 2);
        // no replica registered a prefix pool -> null, not zeros
        assert!(matches!(j.get("prefix"), Some(Json::Null)));
    }

    #[test]
    fn state_label_precedence_and_fault_counters_surface() {
        let t = ReplicaTelemetry::default();
        assert_eq!(t.state(), "ready");
        t.draining.store(true, Ordering::Relaxed);
        assert_eq!(t.state(), "draining");
        t.down.store(true, Ordering::Relaxed);
        assert_eq!(t.state(), "failed", "failed outranks draining");
        t.restarting.store(true, Ordering::Relaxed);
        assert_eq!(t.state(), "restarting");
        t.restarting.store(false, Ordering::Relaxed);
        t.down.store(false, Ordering::Relaxed);
        t.restarts.store(3, Ordering::Relaxed);
        t.deadline_exceeded.store(2, Ordering::Relaxed);
        t.restart_us.lock().unwrap().record(1500.0);
        let j = t.snapshot(0, ReplicaRole::Mixed, 1.0);
        assert_eq!(j.req_str("state").unwrap(), "draining");
        assert_eq!(j.req_usize("restarts").unwrap(), 3);
        assert_eq!(j.req_usize("deadline_exceeded").unwrap(), 2);
        assert_eq!(j.get("restart_us").unwrap().req_usize("count").unwrap(), 1);
        let agg = pool_stats_json(
            &PoolTelemetry::default(),
            &[Arc::new(t)],
            &[ReplicaRole::Mixed],
            1.0,
            false,
            None,
        );
        assert_eq!(agg.req_usize("restarts").unwrap(), 3);
        assert_eq!(agg.req_usize("deadline_exceeded").unwrap(), 2);
        assert_eq!(agg.req_usize("failed_replicas").unwrap(), 0);
        assert!(agg.get("faults_injected").is_some());
    }

    #[test]
    fn prefix_pool_counters_surface_in_stats() {
        let a = Arc::new(ReplicaTelemetry::default());
        let b = Arc::new(ReplicaTelemetry::default());
        let pool = Arc::new(PrefixPool::new(4));
        pool.publish(11, Vec::new());
        assert!(pool.probe(11).is_some());
        assert!(pool.probe(99).is_none());
        *a.prefix_pool.lock().unwrap() = Some(pool.clone());
        assert!(a.advertises(11), "registered chunk must be advertised");
        assert!(!a.advertises(99));
        assert!(!b.advertises(11), "pool is per-replica");
        let j = a.snapshot(0, ReplicaRole::Mixed, 1.0);
        let p = j.get("prefix").unwrap();
        assert_eq!(p.req_usize("hits").unwrap(), 1);
        assert_eq!(p.req_usize("misses").unwrap(), 1);
        assert_eq!(p.req_usize("published").unwrap(), 1);
        assert_eq!(p.req_usize("entries").unwrap(), 1);
        let agg = pool_stats_json(
            &PoolTelemetry::default(),
            &[a, b],
            &[ReplicaRole::Mixed, ReplicaRole::Mixed],
            1.0,
            false,
            None,
        );
        let p = agg.get("prefix").unwrap();
        assert_eq!(p.req_usize("hits").unwrap(), 1, "aggregated across replicas");
    }

    #[test]
    fn tier_counters_surface_in_stats() {
        let mut s = TierStats {
            sessions: 2,
            hot_blocks: 5,
            dram_budget_blocks: 8,
            hot_bytes: 4096,
            cold_bytes: 2048,
            spill_file_bytes: 8192,
            suspended: 3,
            resumed: 1,
            spilled: 2,
            paged_in: 2,
            shed: 0,
            evicted: 1,
            misses: 1,
            compactions: 1,
            page_in_us: crate::metrics::Histogram::new(),
        };
        s.page_in_us.record(250.0);
        let j = pool_stats_json(
            &PoolTelemetry::default(),
            &[Arc::new(ReplicaTelemetry::default())],
            &[ReplicaRole::Mixed],
            1.0,
            false,
            Some(&s),
        );
        let t = j.get("tier").unwrap();
        assert_eq!(t.req_usize("sessions").unwrap(), 2);
        assert_eq!(t.req_usize("suspended").unwrap(), 3);
        assert_eq!(t.req_usize("spilled").unwrap(), 2);
        assert_eq!(t.req_usize("spill_file_bytes").unwrap(), 8192);
        assert_eq!(t.get("page_in_us").unwrap().req_usize("count").unwrap(), 1);
        // disabled tier -> null, not zeros (byte-identical default plane)
        let off = pool_stats_json(
            &PoolTelemetry::default(),
            &[Arc::new(ReplicaTelemetry::default())],
            &[ReplicaRole::Mixed],
            1.0,
            false,
            None,
        );
        assert!(matches!(off.get("tier"), Some(Json::Null)));
    }
}
