//! Request router: places work onto replicas under a pluggable policy.
//!
//! Placement is **two-stage** under prefill/decode disaggregation: an
//! admission first lands on a *prefill-capable* replica
//! ([`Router::pick_prefill`]); once its prompt is in the KV cache the
//! finished sequence is handed to a *decode-capable* replica
//! ([`Router::pick_decode`]). With the default all-[`Mixed`] role mask
//! both stages resolve to the same replica set and stage two always
//! picks the prefilling replica itself — exactly the single-stage
//! behavior before disaggregation.
//!
//! Placement is advisory — admission control (bounded queues + token
//! budget) still has the final word at the chosen replica.
//!
//! [`Mixed`]: ReplicaRole::Mixed

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use super::telemetry::ReplicaTelemetry;

/// What work a replica accepts (the disaggregation role mask).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ReplicaRole {
    /// Prefills admissions, decodes nothing: every finished prefill is
    /// handed off.
    Prefill,
    /// Decodes handed-off sequences, admits nothing directly.
    Decode,
    /// Both (the default — preserves pre-disaggregation behavior).
    #[default]
    Mixed,
}

impl ReplicaRole {
    pub fn can_prefill(&self) -> bool {
        matches!(self, ReplicaRole::Prefill | ReplicaRole::Mixed)
    }

    pub fn can_decode(&self) -> bool {
        matches!(self, ReplicaRole::Decode | ReplicaRole::Mixed)
    }

    pub fn label(&self) -> &'static str {
        match self {
            ReplicaRole::Prefill => "prefill",
            ReplicaRole::Decode => "decode",
            ReplicaRole::Mixed => "mixed",
        }
    }
}

impl std::str::FromStr for ReplicaRole {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "prefill" => Ok(ReplicaRole::Prefill),
            "decode" => Ok(ReplicaRole::Decode),
            "mixed" | "both" => Ok(ReplicaRole::Mixed),
            other => anyhow::bail!("unknown replica role {other:?}"),
        }
    }
}

/// Placement policy across the engine pool.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RoutePolicy {
    /// Argmin over replicas of reserved in-flight tokens (queued + live) —
    /// the default; balances mixed-length traffic better than counts.
    #[default]
    LeastLoaded,
    /// Strict rotation, ignoring load.
    RoundRobin,
    /// Hash the request's session key onto a fixed replica so one
    /// conversation keeps hitting the same engine (KV reuse locality);
    /// sessionless requests fall back to least-loaded.
    SessionAffinity,
}

impl RoutePolicy {
    pub fn label(&self) -> &'static str {
        match self {
            RoutePolicy::LeastLoaded => "least_loaded",
            RoutePolicy::RoundRobin => "round_robin",
            RoutePolicy::SessionAffinity => "session_affinity",
        }
    }
}

impl std::str::FromStr for RoutePolicy {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().replace('-', "_").as_str() {
            "least_loaded" | "leastloaded" | "load" => Ok(RoutePolicy::LeastLoaded),
            "round_robin" | "roundrobin" | "rr" => Ok(RoutePolicy::RoundRobin),
            "session_affinity" | "session" | "affinity" => Ok(RoutePolicy::SessionAffinity),
            other => anyhow::bail!("unknown route policy {other:?}"),
        }
    }
}

/// The two placement stages of a request's lifecycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Stage {
    Prefill,
    Decode,
}

/// Stateful placement over a fixed replica set.
pub struct Router {
    policy: RoutePolicy,
    replicas: Vec<Arc<ReplicaTelemetry>>,
    roles: Vec<ReplicaRole>,
    rr_next: AtomicUsize,
}

impl Router {
    pub fn new(
        policy: RoutePolicy,
        replicas: Vec<Arc<ReplicaTelemetry>>,
        roles: Vec<ReplicaRole>,
    ) -> Self {
        assert!(!replicas.is_empty(), "router needs at least one replica");
        assert_eq!(replicas.len(), roles.len(), "one role per replica");
        Self { policy, replicas, roles, rr_next: AtomicUsize::new(0) }
    }

    pub fn policy(&self) -> RoutePolicy {
        self.policy
    }

    pub fn roles(&self) -> &[ReplicaRole] {
        &self.roles
    }

    /// Whether the pool actually separates roles. All-`Mixed` pools skip
    /// stage-two placement entirely (each replica keeps its own
    /// admissions — pre-disaggregation behavior, byte for byte).
    pub fn disaggregated(&self) -> bool {
        self.roles.iter().any(|r| *r != ReplicaRole::Mixed)
    }

    /// Stage 1: choose a replica to *prefill* a new admission. `None`
    /// only when no replica can prefill at all (prevented by config
    /// validation).
    pub fn pick_prefill(&self, session: Option<&str>) -> Option<usize> {
        self.pick(Stage::Prefill, session)
    }

    /// [`Self::pick_prefill`] with a prefix-locality hint: `hint` is
    /// the chained hash of the request's first prompt chunk. A replica
    /// whose prefix pool advertises that chunk is *preferred* — the
    /// cached blocks only save work where they live — but never
    /// required: advertisers are filtered down from the same eligible
    /// set as plain placement (role-capable, not draining), so a
    /// preferred-but-draining or role-masked replica falls back to the
    /// ordinary policy. Ties between several advertisers break
    /// least-loaded.
    pub fn pick_prefill_with_hint(&self, session: Option<&str>, hint: Option<u64>) -> Option<usize> {
        if let Some(key) = hint {
            let mut eligible = self.eligible(Stage::Prefill);
            eligible.retain(|&i| self.replicas[i].advertises(key));
            if !eligible.is_empty() {
                return Some(self.least_loaded(&eligible));
            }
        }
        self.pick(Stage::Prefill, session)
    }

    /// Stage 2: choose a replica to *decode* a prefilled sequence.
    /// Affinity hashes over the full replica set (stable under role
    /// reconfiguration); a hash landing on a draining or non-decode
    /// replica falls back to the least-loaded eligible one — a session
    /// must never hang or land on a prefill-only replica.
    pub fn pick_decode(&self, session: Option<&str>) -> Option<usize> {
        self.pick(Stage::Decode, session)
    }

    /// Replicas a `stage` placement may legally target right now.
    fn eligible(&self, stage: Stage) -> Vec<usize> {
        let can = |i: usize| match stage {
            Stage::Prefill => self.roles[i].can_prefill(),
            Stage::Decode => self.roles[i].can_decode(),
        };
        // ordering: advisory routing hint only — a stale read just routes
        // one more request to a replica that is draining or was just
        // marked failed, and admission is re-checked under the pool's
        // senders mutex (a send to a failed replica's queue is refused
        // when its supervisor recovers).
        let live = |i: usize| !self.replicas[i].draining.load(Ordering::Relaxed);
        let up = |i: usize| !self.replicas[i].down.load(Ordering::Relaxed);
        // Draining replicas are skipped while any capable live replica
        // exists; accepted work must still land somewhere when the whole
        // pool is draining, so the up, role-capable set is the fallback.
        // Failed (`down`) replicas are excluded even from that fallback:
        // routing to a dead engine strands the request, while routing to
        // a draining one merely gets it refused politely.
        let mut eligible: Vec<usize> =
            (0..self.replicas.len()).filter(|&i| can(i) && up(i) && live(i)).collect();
        if eligible.is_empty() {
            eligible = (0..self.replicas.len()).filter(|&i| can(i) && up(i)).collect();
        }
        eligible
    }

    fn pick(&self, stage: Stage, session: Option<&str>) -> Option<usize> {
        let eligible = self.eligible(stage);
        if eligible.is_empty() {
            return None;
        }
        Some(match self.policy {
            RoutePolicy::RoundRobin => {
                // ordering: pure round-robin cursor — fairness needs only
                // the fetch_add's RMW atomicity, not inter-thread order.
                eligible[self.rr_next.fetch_add(1, Ordering::Relaxed) % eligible.len()]
            }
            RoutePolicy::LeastLoaded => self.least_loaded(&eligible),
            RoutePolicy::SessionAffinity => match session {
                Some(key) => {
                    let affine = (fnv1a(key.as_bytes()) as usize) % self.replicas.len();
                    if eligible.contains(&affine) {
                        affine
                    } else {
                        self.least_loaded(&eligible)
                    }
                }
                None => self.least_loaded(&eligible),
            },
        })
    }

    fn least_loaded(&self, candidates: &[usize]) -> usize {
        let mut best = candidates[0];
        let mut best_load = usize::MAX;
        for &i in candidates {
            let r = &self.replicas[i];
            // Tie-break on queue depth so an idle replica with equal
            // reserved tokens still wins.
            let load = r.load_tokens().saturating_mul(1024) + r.depth();
            if load < best_load {
                best = i;
                best_load = load;
            }
        }
        best
    }
}

/// FNV-1a, the classic tiny stable hash (no std::hash — RandomState
/// would re-place sessions across process restarts).
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    fn replicas(n: usize) -> Vec<Arc<ReplicaTelemetry>> {
        (0..n).map(|_| Arc::new(ReplicaTelemetry::default())).collect()
    }

    fn mixed(n: usize) -> Vec<ReplicaRole> {
        vec![ReplicaRole::Mixed; n]
    }

    #[test]
    fn policy_parse_roundtrip() {
        for p in [RoutePolicy::LeastLoaded, RoutePolicy::RoundRobin, RoutePolicy::SessionAffinity] {
            let back: RoutePolicy = p.label().parse().unwrap();
            assert_eq!(back, p);
        }
        assert_eq!("rr".parse::<RoutePolicy>().unwrap(), RoutePolicy::RoundRobin);
        assert!("bogus".parse::<RoutePolicy>().is_err());
    }

    #[test]
    fn role_parse_roundtrip() {
        for r in [ReplicaRole::Prefill, ReplicaRole::Decode, ReplicaRole::Mixed] {
            let back: ReplicaRole = r.label().parse().unwrap();
            assert_eq!(back, r);
        }
        assert!("bogus".parse::<ReplicaRole>().is_err());
        assert!(ReplicaRole::Mixed.can_prefill() && ReplicaRole::Mixed.can_decode());
        assert!(ReplicaRole::Prefill.can_prefill() && !ReplicaRole::Prefill.can_decode());
        assert!(!ReplicaRole::Decode.can_prefill() && ReplicaRole::Decode.can_decode());
    }

    #[test]
    fn round_robin_rotates() {
        let r = Router::new(RoutePolicy::RoundRobin, replicas(3), mixed(3));
        assert_eq!(
            (0..6).map(|_| r.pick_prefill(None).unwrap()).collect::<Vec<_>>(),
            vec![0, 1, 2, 0, 1, 2]
        );
    }

    #[test]
    fn least_loaded_prefers_light_replica() {
        let reps = replicas(3);
        reps[0].live_tokens.store(500, Ordering::Relaxed);
        reps[1].live_tokens.store(20, Ordering::Relaxed);
        reps[2].live_tokens.store(300, Ordering::Relaxed);
        let r = Router::new(RoutePolicy::LeastLoaded, reps, mixed(3));
        assert_eq!(r.pick_prefill(None), Some(1));
        assert_eq!(r.pick_decode(None), Some(1));
    }

    #[test]
    fn session_affinity_is_sticky_and_spreads() {
        let r = Router::new(RoutePolicy::SessionAffinity, replicas(4), mixed(4));
        let a = r.pick_decode(Some("user-a")).unwrap();
        for _ in 0..5 {
            assert_eq!(r.pick_decode(Some("user-a")), Some(a));
        }
        // distinct keys should not all collapse onto one replica
        let picks: std::collections::HashSet<usize> = (0..32)
            .map(|i| r.pick_decode(Some(&format!("user-{i}"))).unwrap())
            .collect();
        assert!(picks.len() > 1, "affinity hash degenerate: {picks:?}");
    }

    #[test]
    fn roles_gate_each_stage() {
        let roles = vec![ReplicaRole::Prefill, ReplicaRole::Decode, ReplicaRole::Decode];
        let r = Router::new(RoutePolicy::LeastLoaded, replicas(3), roles);
        assert!(r.disaggregated());
        // admissions only ever land on the prefill replica
        for _ in 0..4 {
            assert_eq!(r.pick_prefill(None), Some(0));
        }
        // decode placement never lands on the prefill-only replica
        for _ in 0..4 {
            assert_ne!(r.pick_decode(None), Some(0));
        }
        let all_mixed = Router::new(RoutePolicy::LeastLoaded, replicas(2), mixed(2));
        assert!(!all_mixed.disaggregated());
    }

    #[test]
    fn affinity_falls_back_off_role_masked_replicas() {
        // Find a session whose affine replica (hash % 4) is index 0,
        // then mask 0 prefill-only: decode placement must fall back to
        // a decode-capable replica — never 0, never None.
        let session = (0..256)
            .map(|i| format!("s-{i}"))
            .find(|s| (fnv1a(s.as_bytes()) as usize) % 4 == 0)
            .expect("some session hashes to replica 0");
        let roles = vec![
            ReplicaRole::Prefill,
            ReplicaRole::Decode,
            ReplicaRole::Mixed,
            ReplicaRole::Decode,
        ];
        let r = Router::new(RoutePolicy::SessionAffinity, replicas(4), roles);
        for _ in 0..8 {
            let pick = r.pick_decode(Some(&session)).expect("must not hang");
            assert_ne!(pick, 0, "fell onto the prefill-only replica");
        }
        // ...and a session affine to a decode-capable replica sticks.
        let sticky = (0..256)
            .map(|i| format!("t-{i}"))
            .find(|s| (fnv1a(s.as_bytes()) as usize) % 4 == 1)
            .unwrap();
        assert_eq!(r.pick_decode(Some(&sticky)), Some(1));
    }

    #[test]
    fn affinity_falls_back_off_draining_replicas() {
        let reps = replicas(3);
        let session = (0..256)
            .map(|i| format!("d-{i}"))
            .find(|s| (fnv1a(s.as_bytes()) as usize) % 3 == 2)
            .unwrap();
        let r = Router::new(RoutePolicy::SessionAffinity, reps.clone(), mixed(3));
        assert_eq!(r.pick_decode(Some(&session)), Some(2));
        reps[2].draining.store(true, Ordering::Relaxed);
        for _ in 0..8 {
            let pick = r.pick_decode(Some(&session)).expect("must not hang");
            assert_ne!(pick, 2, "landed on a draining replica");
            assert_eq!(r.pick_prefill(Some(&session)).map(|p| p == 2), Some(false));
        }
        // every capable replica draining: accepted work must still land
        for rep in &reps {
            rep.draining.store(true, Ordering::Relaxed);
        }
        assert!(r.pick_decode(Some(&session)).is_some(), "drain must not strand handoffs");
    }

    #[test]
    fn failed_replicas_are_excluded_even_from_the_draining_fallback() {
        let reps = replicas(3);
        reps[1].down.store(true, Ordering::Relaxed);
        let r = Router::new(RoutePolicy::RoundRobin, reps.clone(), mixed(3));
        for _ in 0..8 {
            let pick = r.pick_prefill(None).expect("survivors must still place");
            assert_ne!(pick, 1, "routed to a failed replica");
            assert_ne!(r.pick_decode(None), Some(1));
        }
        // Whole pool draining: the fallback may use draining replicas
        // but still never the failed one.
        for rep in &reps {
            rep.draining.store(true, Ordering::Relaxed);
        }
        for _ in 0..8 {
            assert_ne!(r.pick_decode(None), Some(1), "failed replica used as drain fallback");
        }
        // Every replica failed: placement must refuse, not strand.
        for rep in &reps {
            rep.down.store(true, Ordering::Relaxed);
        }
        assert_eq!(r.pick_prefill(None), None);
        // Recovery: the supervisor clears `down` and the replica is
        // placeable again (drain flags cleared too for a clean check).
        for rep in &reps {
            rep.down.store(false, Ordering::Relaxed);
            rep.draining.store(false, Ordering::Relaxed);
        }
        let picks: std::collections::HashSet<usize> =
            (0..6).map(|_| r.pick_prefill(None).unwrap()).collect();
        assert!(picks.contains(&1), "respawned replica never returned to rotation");
    }

    #[test]
    fn prefix_hint_falls_back_off_failed_advertiser() {
        let reps = replicas(3);
        reps[1].live_tokens.store(50, Ordering::Relaxed);
        advertise(&reps, 2, 0xfeed);
        reps[2].down.store(true, Ordering::Relaxed);
        let r = Router::new(RoutePolicy::LeastLoaded, reps, mixed(3));
        assert_eq!(r.pick_prefill_with_hint(None, Some(0xfeed)), Some(0));
    }

    #[test]
    fn no_capable_replica_yields_none() {
        let roles = vec![ReplicaRole::Decode, ReplicaRole::Decode];
        let r = Router::new(RoutePolicy::LeastLoaded, replicas(2), roles);
        assert_eq!(r.pick_prefill(None), None, "nothing can prefill");
        assert!(r.pick_decode(None).is_some());
    }

    /// Give replica `i` a prefix pool advertising `key`.
    fn advertise(reps: &[Arc<ReplicaTelemetry>], i: usize, key: u64) {
        use crate::kvcache::PrefixPool;
        let pool = Arc::new(PrefixPool::new(8));
        pool.publish(key, Vec::new());
        *reps[i].prefix_pool.lock().unwrap() = Some(pool);
    }

    #[test]
    fn prefix_hint_prefers_advertising_replica_over_lighter_load() {
        let reps = replicas(3);
        // replica 2 advertises the chunk but carries MORE load than 1 —
        // locality must still win (recomputing 2k prompt tokens costs
        // more than the load skew).
        reps[1].live_tokens.store(10, Ordering::Relaxed);
        reps[2].live_tokens.store(400, Ordering::Relaxed);
        advertise(&reps, 2, 0xfeed);
        let r = Router::new(RoutePolicy::LeastLoaded, reps, mixed(3));
        assert_eq!(r.pick_prefill_with_hint(None, Some(0xfeed)), Some(2));
        // no hint, or a chunk nobody holds: plain least-loaded placement
        assert_eq!(r.pick_prefill_with_hint(None, None), Some(1));
        assert_eq!(r.pick_prefill_with_hint(None, Some(0xdead)), Some(1));
    }

    #[test]
    fn prefix_hint_breaks_advertiser_ties_least_loaded() {
        let reps = replicas(3);
        advertise(&reps, 0, 0xfeed);
        advertise(&reps, 2, 0xfeed);
        reps[0].live_tokens.store(300, Ordering::Relaxed);
        reps[2].live_tokens.store(30, Ordering::Relaxed);
        let r = Router::new(RoutePolicy::LeastLoaded, reps, mixed(3));
        assert_eq!(r.pick_prefill_with_hint(None, Some(0xfeed)), Some(2));
    }

    #[test]
    fn prefix_hint_falls_back_off_draining_advertiser() {
        let reps = replicas(3);
        reps[1].live_tokens.store(50, Ordering::Relaxed);
        advertise(&reps, 2, 0xfeed);
        reps[2].draining.store(true, Ordering::Relaxed);
        let r = Router::new(RoutePolicy::LeastLoaded, reps, mixed(3));
        // the only advertiser is draining: hint must not pin work onto
        // it — fall back to ordinary least-loaded over live replicas.
        assert_eq!(r.pick_prefill_with_hint(None, Some(0xfeed)), Some(0));
    }

    #[test]
    fn prefix_hint_falls_back_off_role_masked_advertiser() {
        let reps = replicas(3);
        reps[1].live_tokens.store(50, Ordering::Relaxed);
        advertise(&reps, 2, 0xfeed);
        let roles = vec![ReplicaRole::Mixed, ReplicaRole::Mixed, ReplicaRole::Decode];
        let r = Router::new(RoutePolicy::LeastLoaded, reps, roles);
        // the advertiser cannot prefill at all: the hint is void.
        assert_eq!(r.pick_prefill_with_hint(None, Some(0xfeed)), Some(0));
    }

    #[test]
    fn prefix_hint_defers_to_session_affinity_on_miss() {
        // With no advertiser the hinted path must be byte-identical to
        // pick_prefill — including the session-affinity policy.
        let r = Router::new(RoutePolicy::SessionAffinity, replicas(4), mixed(4));
        let session = (0..256)
            .map(|i| format!("p-{i}"))
            .find(|s| (fnv1a(s.as_bytes()) as usize) % 4 == 3)
            .unwrap();
        assert_eq!(
            r.pick_prefill_with_hint(Some(&session), Some(0xfeed)),
            r.pick_prefill(Some(&session)),
        );
        assert_eq!(r.pick_prefill_with_hint(Some(&session), None), Some(3));
    }
}
