//! Request router: places submissions onto replicas under a pluggable
//! policy. Placement is advisory — admission control (bounded queues +
//! token budget) still has the final word at the chosen replica.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use super::telemetry::ReplicaTelemetry;

/// Placement policy across the engine pool.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RoutePolicy {
    /// Argmin over replicas of reserved in-flight tokens (queued + live) —
    /// the default; balances mixed-length traffic better than counts.
    #[default]
    LeastLoaded,
    /// Strict rotation, ignoring load.
    RoundRobin,
    /// Hash the request's session key onto a fixed replica so one
    /// conversation keeps hitting the same engine (KV reuse locality);
    /// sessionless requests fall back to least-loaded.
    SessionAffinity,
}

impl RoutePolicy {
    pub fn label(&self) -> &'static str {
        match self {
            RoutePolicy::LeastLoaded => "least_loaded",
            RoutePolicy::RoundRobin => "round_robin",
            RoutePolicy::SessionAffinity => "session_affinity",
        }
    }
}

impl std::str::FromStr for RoutePolicy {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().replace('-', "_").as_str() {
            "least_loaded" | "leastloaded" | "load" => Ok(RoutePolicy::LeastLoaded),
            "round_robin" | "roundrobin" | "rr" => Ok(RoutePolicy::RoundRobin),
            "session_affinity" | "session" | "affinity" => Ok(RoutePolicy::SessionAffinity),
            other => anyhow::bail!("unknown route policy {other:?}"),
        }
    }
}

/// Stateful placement over a fixed replica set.
pub struct Router {
    policy: RoutePolicy,
    replicas: Vec<Arc<ReplicaTelemetry>>,
    rr_next: AtomicUsize,
}

impl Router {
    pub fn new(policy: RoutePolicy, replicas: Vec<Arc<ReplicaTelemetry>>) -> Self {
        assert!(!replicas.is_empty(), "router needs at least one replica");
        Self { policy, replicas, rr_next: AtomicUsize::new(0) }
    }

    pub fn policy(&self) -> RoutePolicy {
        self.policy
    }

    /// Choose a replica index for a request carrying `session`.
    pub fn pick(&self, session: Option<&str>) -> usize {
        match self.policy {
            RoutePolicy::RoundRobin => self.round_robin(),
            RoutePolicy::LeastLoaded => self.least_loaded(),
            RoutePolicy::SessionAffinity => match session {
                Some(key) => (fnv1a(key.as_bytes()) as usize) % self.replicas.len(),
                None => self.least_loaded(),
            },
        }
    }

    fn round_robin(&self) -> usize {
        self.rr_next.fetch_add(1, Ordering::Relaxed) % self.replicas.len()
    }

    fn least_loaded(&self) -> usize {
        let mut best = 0usize;
        let mut best_load = usize::MAX;
        for (i, r) in self.replicas.iter().enumerate() {
            // Tie-break on queue depth so an idle replica with equal
            // reserved tokens still wins.
            let load = r.load_tokens().saturating_mul(1024) + r.depth();
            if load < best_load {
                best = i;
                best_load = load;
            }
        }
        best
    }
}

/// FNV-1a, the classic tiny stable hash (no std::hash — RandomState
/// would re-place sessions across process restarts).
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    fn replicas(n: usize) -> Vec<Arc<ReplicaTelemetry>> {
        (0..n).map(|_| Arc::new(ReplicaTelemetry::default())).collect()
    }

    #[test]
    fn policy_parse_roundtrip() {
        for p in [RoutePolicy::LeastLoaded, RoutePolicy::RoundRobin, RoutePolicy::SessionAffinity] {
            let back: RoutePolicy = p.label().parse().unwrap();
            assert_eq!(back, p);
        }
        assert_eq!("rr".parse::<RoutePolicy>().unwrap(), RoutePolicy::RoundRobin);
        assert!("bogus".parse::<RoutePolicy>().is_err());
    }

    #[test]
    fn round_robin_rotates() {
        let r = Router::new(RoutePolicy::RoundRobin, replicas(3));
        assert_eq!(
            (0..6).map(|_| r.pick(None)).collect::<Vec<_>>(),
            vec![0, 1, 2, 0, 1, 2]
        );
    }

    #[test]
    fn least_loaded_prefers_light_replica() {
        let reps = replicas(3);
        reps[0].live_tokens.store(500, Ordering::Relaxed);
        reps[1].live_tokens.store(20, Ordering::Relaxed);
        reps[2].live_tokens.store(300, Ordering::Relaxed);
        let r = Router::new(RoutePolicy::LeastLoaded, reps);
        assert_eq!(r.pick(None), 1);
    }

    #[test]
    fn session_affinity_is_sticky_and_spreads() {
        let r = Router::new(RoutePolicy::SessionAffinity, replicas(4));
        let a = r.pick(Some("user-a"));
        for _ in 0..5 {
            assert_eq!(r.pick(Some("user-a")), a);
        }
        // distinct keys should not all collapse onto one replica
        let picks: std::collections::HashSet<usize> =
            (0..32).map(|i| r.pick(Some(&format!("user-{i}")))).collect();
        assert!(picks.len() > 1, "affinity hash degenerate: {picks:?}");
    }
}
