//! Per-request streaming output: the channel contract between a replica
//! engine thread and the client that submitted the request.
//!
//! Every submission gets its own event channel. Replicas publish one
//! [`StreamEvent::Token`] per decode step as soon as the token exists
//! (streaming requests only) and always terminate the stream with exactly
//! one terminal event: `Done`, `Rejected`, `Cancelled`, `Failed`,
//! `ReplicaLost`, or `DeadlineExceeded`. The channel is
//! unbounded on purpose — a slow client must never stall the replica's
//! whole continuous batch, and the event count is bounded by
//! `max_new_tokens + 1` anyway.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::Duration;

use crate::coordinator::RequestOutput;

/// Why a request was refused before reaching a replica batch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RejectCode {
    /// The request can never succeed (context overflow, zero budget).
    Invalid,
    /// Backpressure: queues or the token budget are full; retry later.
    Overloaded,
    /// The pool is draining and admits nothing new.
    Draining,
}

impl RejectCode {
    pub fn label(&self) -> &'static str {
        match self {
            RejectCode::Invalid => "invalid",
            RejectCode::Overloaded => "overloaded",
            RejectCode::Draining => "draining",
        }
    }
}

/// Structured admission refusal (the backpressure contract: a client is
/// always answered, never buffered without bound or hung up on).
#[derive(Debug, Clone)]
pub struct Rejection {
    pub id: u64,
    pub code: RejectCode,
    pub reason: String,
    /// Suggested client backoff. 0 for `Invalid` and `Draining` —
    /// retrying against this endpoint cannot help in either case.
    pub retry_after_ms: u64,
}

/// One event on a request's output stream.
#[derive(Debug, Clone)]
pub enum StreamEvent {
    /// A newly decoded token (published per step for streaming requests).
    Token { id: u64, token: u32, step: usize },
    /// Terminal: the request completed; full output attached.
    Done(RequestOutput),
    /// Terminal: refused by admission control.
    Rejected(Rejection),
    /// Terminal: evicted because the client asked for cancellation
    /// (connection hangup). Distinct from [`StreamEvent::Failed`] so
    /// clients and telemetry can tell an intentional cancel from a real
    /// fault.
    Cancelled { id: u64 },
    /// Terminal: the owning replica hit an engine error.
    Failed { id: u64, error: String },
    /// Terminal: the replica holding this request's decode state died
    /// (panic, watchdog stall, or a handoff to a dead replica) and its
    /// KV cache is unrecoverable. Retryable — the request itself is
    /// fine; resubmitting replays the prompt on a surviving replica
    /// (cheaply, via the prefix pool). Prefill-stage requests are
    /// replayed transparently instead and never see this event.
    ReplicaLost { id: u64, retry_after_ms: u64 },
    /// Terminal: the request's `timeout_ms` deadline passed (checked at
    /// admission, between prefill chunks, and between decode steps).
    DeadlineExceeded { id: u64, elapsed_ms: u64 },
}

pub(crate) type EventSender = Sender<StreamEvent>;

/// Client-side handle to one submitted request.
pub struct StreamHandle {
    /// Pool-assigned request id (echoed in every event).
    pub id: u64,
    /// Replica the router placed the request on for *prefill* (`None`
    /// if rejected before placement; the sequence may decode elsewhere
    /// under disaggregated roles).
    pub replica: Option<usize>,
    rx: Receiver<StreamEvent>,
    /// Shared cancellation flag: travels with the request's tracking
    /// state across replicas (including prefill→decode handoff), so a
    /// cancel needs no routing — whichever replica owns the request
    /// observes the flag between steps and evicts it.
    cancel: Arc<AtomicBool>,
}

impl StreamHandle {
    pub(crate) fn new(
        id: u64,
        replica: Option<usize>,
        rx: Receiver<StreamEvent>,
        cancel: Arc<AtomicBool>,
    ) -> Self {
        Self { id, replica, rx, cancel }
    }

    /// Request cancellation (best-effort; the owning replica evicts the
    /// request between steps). Prefer [`EnginePool::cancel`].
    ///
    /// [`EnginePool::cancel`]: super::EnginePool::cancel
    pub(crate) fn request_cancel(&self) {
        // ordering: Release pairs with the replica loop's Acquire load of
        // this flag — everything the cancelling thread wrote before the
        // store (e.g. its reason for cancelling) is visible to the
        // replica when it observes `true` and emits `Cancelled`.
        self.cancel.store(true, Ordering::Release);
    }

    /// Next event; `None` once the stream is closed (after a terminal
    /// event, or if the replica died without one).
    pub fn recv(&self) -> Option<StreamEvent> {
        self.rx.recv().ok()
    }

    /// Like [`recv`](Self::recv) but bounded — tests use this so a
    /// regression hangs a timeout, not the suite.
    pub fn recv_timeout(&self, timeout: Duration) -> Option<StreamEvent> {
        match self.rx.recv_timeout(timeout) {
            Ok(ev) => Some(ev),
            Err(RecvTimeoutError::Timeout) | Err(RecvTimeoutError::Disconnected) => None,
        }
    }

    /// Drain the stream to its terminal event and return the completed
    /// output. Token events are checked against the final output so a
    /// streaming-order bug cannot pass silently.
    pub fn wait(self) -> crate::Result<RequestOutput> {
        let mut streamed: Vec<u32> = Vec::new();
        while let Some(ev) = self.recv() {
            match ev {
                StreamEvent::Token { token, .. } => streamed.push(token),
                StreamEvent::Done(out) => {
                    if !streamed.is_empty() {
                        anyhow::ensure!(
                            streamed == out.generated,
                            "stream/final divergence for request {}",
                            out.id
                        );
                    }
                    return Ok(out);
                }
                StreamEvent::Rejected(r) => {
                    anyhow::bail!(
                        "request {} rejected ({}): {} (retry_after_ms {})",
                        r.id,
                        r.code.label(),
                        r.reason,
                        r.retry_after_ms
                    )
                }
                StreamEvent::Cancelled { id } => {
                    anyhow::bail!("request {id} cancelled: client disconnected")
                }
                StreamEvent::Failed { id, error } => {
                    anyhow::bail!("request {id} failed on replica: {error}")
                }
                StreamEvent::ReplicaLost { id, retry_after_ms } => {
                    anyhow::bail!(
                        "request {id}: replica lost, retryable (retry_after_ms {retry_after_ms})"
                    )
                }
                StreamEvent::DeadlineExceeded { id, elapsed_ms } => {
                    anyhow::bail!("request {id}: deadline exceeded after {elapsed_ms} ms")
                }
            }
        }
        anyhow::bail!("request {}: stream closed without a terminal event", self.id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    fn handle(id: u64, replica: Option<usize>, rx: Receiver<StreamEvent>) -> StreamHandle {
        StreamHandle::new(id, replica, rx, Arc::new(AtomicBool::new(false)))
    }

    #[test]
    fn wait_collects_tokens_and_checks_order() {
        let (tx, rx) = channel();
        let h = handle(1, Some(0), rx);
        tx.send(StreamEvent::Token { id: 1, token: 5, step: 1 }).unwrap();
        tx.send(StreamEvent::Token { id: 1, token: 9, step: 2 }).unwrap();
        tx.send(StreamEvent::Done(RequestOutput {
            id: 1,
            generated: vec![5, 9],
            steps: 2,
            decode_wall_us: 1,
            queue_us: 0,
            ttft_us: 0,
        }))
        .unwrap();
        let out = h.wait().unwrap();
        assert_eq!(out.generated, vec![5, 9]);
    }

    #[test]
    fn wait_surfaces_rejection() {
        let (tx, rx) = channel();
        let h = handle(2, None, rx);
        tx.send(StreamEvent::Rejected(Rejection {
            id: 2,
            code: RejectCode::Overloaded,
            reason: "queue full".into(),
            retry_after_ms: 20,
        }))
        .unwrap();
        let err = h.wait().unwrap_err().to_string();
        assert!(err.contains("overloaded"), "{err}");
        assert!(err.contains("retry_after_ms 20"), "{err}");
    }

    #[test]
    fn wait_flags_stream_divergence() {
        let (tx, rx) = channel();
        let h = handle(3, Some(0), rx);
        tx.send(StreamEvent::Token { id: 3, token: 5, step: 1 }).unwrap();
        tx.send(StreamEvent::Done(RequestOutput {
            id: 3,
            generated: vec![6],
            steps: 1,
            decode_wall_us: 1,
            queue_us: 0,
            ttft_us: 0,
        }))
        .unwrap();
        assert!(h.wait().is_err());
    }
}
