//! `scout` — the ScoutAttention leader binary.
//!
//! Subcommands:
//!   serve        run the JSON-lines TCP server (python-free request path)
//!   run          offline serving run, prints throughput + schedule stats
//!   sim          timing-plane simulation of the paper's figures
//!   trace        ASCII Gantt of each method's pipeline (Fig. 1)
//!   tab1         query-predictability study across the proxy model zoo
//!   drift        CPU-compute-ratio drift + recall profiling (Fig. 6)
//!   warmup       compile all artifacts for a preset
//!   dump-config  print the effective JSON config
//!
//! Global flags: --config FILE.json, --preset NAME, --artifacts-dir DIR,
//! --method fullkv|infinigen|hgca|scout. (Hand-rolled parsing — the
//! offline crate universe has no clap.)

use scoutattention::config::{Method, RunConfig};
use scoutattention::harness::{self, Stack};
use scoutattention::sim::pipeline::{MethodSim, SynthWorkload};
use scoutattention::sim::{trace, timing::DeviceModel};
use scoutattention::workload::{LengthMix, WorkloadGen};

const USAGE: &str = "usage: scout [--config F] [--preset P] [--artifacts-dir D] [--method M] <cmd>
  serve [--replicas N] [--route least_loaded|round_robin|session_affinity]
        [--roles prefill,decode,...] [--prefill-chunk N]
  run   [--requests N] [--prompt-len N] [--new-tokens N]
  sim   [--seq-len N] [--batch N] [--steps N]
  trace
  tab1
  drift [--steps N]
  warmup
  dump-config";

/// Minimal flag parser: --key value pairs + one positional subcommand.
struct Args {
    cmd: String,
    flags: std::collections::HashMap<String, String>,
}

impl Args {
    fn parse() -> anyhow::Result<Self> {
        let mut cmd = None;
        let mut flags = std::collections::HashMap::new();
        let mut it = std::env::args().skip(1);
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                let v = it
                    .next()
                    .ok_or_else(|| anyhow::anyhow!("flag --{key} needs a value\n{USAGE}"))?;
                flags.insert(key.to_string(), v);
            } else if cmd.is_none() {
                cmd = Some(a);
            } else {
                anyhow::bail!("unexpected argument {a:?}\n{USAGE}");
            }
        }
        Ok(Self { cmd: cmd.ok_or_else(|| anyhow::anyhow!(USAGE))?, flags })
    }

    fn get_usize(&self, key: &str, default: usize) -> anyhow::Result<usize> {
        match self.flags.get(key) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }
}

fn load_config(args: &Args) -> scoutattention::Result<RunConfig> {
    let mut cfg = match args.get("config") {
        Some(p) => RunConfig::from_json_file(p)?,
        None => RunConfig::for_preset(args.get("preset").unwrap_or("serve-20m")),
    };
    if args.get("config").is_none() {
        cfg.artifacts_dir = args.get("artifacts-dir").unwrap_or("artifacts").to_string();
    }
    if let Some(m) = args.get("method") {
        cfg.method = m.parse()?;
    }
    if let Some(s) = args.get("seed") {
        cfg.seed = s.parse()?;
    }
    cfg.validate()?;
    Ok(cfg)
}

fn main() -> scoutattention::Result<()> {
    let args = Args::parse()?;
    let mut cfg = load_config(&args)?;
    match args.cmd.as_str() {
        "serve" => {
            if let Some(r) = args.get("replicas") {
                cfg.server.replicas = r.parse()?;
            }
            if let Some(p) = args.get("route") {
                cfg.server.policy = p.parse()?;
            }
            if let Some(r) = args.get("roles") {
                cfg.server.roles = r
                    .split(',')
                    .map(|s| s.trim().parse())
                    .collect::<scoutattention::Result<Vec<_>>>()?;
            }
            if let Some(c) = args.get("prefill-chunk") {
                cfg.scout.prefill_chunk = c.parse()?;
            }
            cfg.validate()?;
            scoutattention::server::serve(cfg)?
        }
        "run" => {
            let requests = args.get_usize("requests", 8)?;
            let new_tokens = args.get_usize("new-tokens", 32)?;
            let stack = Stack::load(&cfg)?;
            let spec = stack.gpu.spec.clone();
            let prompt_len = args
                .get_usize("prompt-len", 256)?
                .min(spec.max_seq - new_tokens - 1);
            let mut gen =
                WorkloadGen::new(cfg.seed, spec.vocab, LengthMix::Fixed(prompt_len), new_tokens);
            let reqs = gen.take(requests);
            let run = harness::run_method(&stack, cfg.method, reqs, 10_000, None)?;
            println!("method           : {}", cfg.method.label());
            println!("requests         : {}", run.outputs.len());
            println!(
                "admitted         : {} (peak queue depth {})",
                run.total_admitted(),
                run.peak_queue_depth()
            );
            println!(
                "tokens generated : {}",
                run.outputs.iter().map(|o| o.generated.len()).sum::<usize>()
            );
            println!("wall time        : {:.2} s", run.wall_us as f64 / 1e6);
            println!("wall throughput  : {:.1} tok/s", run.wall_throughput_tps());
            println!("mean CPU ratio   : {:.3}", run.mean_cpu_ratio());
            let recall: usize = run.stats.iter().map(|s| s.recall_blocks()).sum();
            println!("recall volume    : {recall} blocks");
            println!("-- slowest artifact calls --");
            for (name, n, dt) in stack.rt.counters.snapshot().into_iter().take(5) {
                println!("  {name:<18} x{n:<6} {:.1} ms total", dt.as_secs_f64() * 1e3);
            }
        }
        "sim" => {
            let seq_len = args.get_usize("seq-len", 32768)?;
            let batch = args.get_usize("batch", 40)?;
            let steps = args.get_usize("steps", 128)?;
            let mut w = SynthWorkload::paper_default(seq_len, batch);
            w.steps = steps;
            println!(
                "timing-plane simulation: {seq_len}-token context, batch {batch}, {steps} steps"
            );
            println!("{:<15} {:>12} {:>8} {:>10}", "method", "tok/s", "idle%", "step(ms)");
            for m in Method::ALL {
                let mut sim = MethodSim::new(m, cfg.device.clone());
                if m != Method::Scout {
                    sim.periodic_recall = false;
                }
                let r = sim.run(&w);
                println!(
                    "{:<15} {:>12.1} {:>7.1}% {:>10.2}",
                    r.method,
                    r.throughput_tps(),
                    r.idle_fraction() * 100.0,
                    r.total_us / r.steps as f64 / 1000.0
                );
            }
        }
        "trace" => {
            let m: DeviceModel = cfg.device.clone();
            // paper anchors: attn 300us/layer at the 4k budget, CPU share
            // ~12% of the budget, InfiniGen recalls ~30% of budget/layer
            let kv = m.kv_layer_bytes(4096) * 40.0;
            let t_attn = m.gpu_attn_us(kv);
            let t_cpu = m.cpu_attn_us(kv * 0.12, 1.0);
            let t_cpu_hgca = m.cpu_attn_us(kv * 0.75, 1.0);
            let t_io = 0.3 * 64.0 * m.pcie_msg_overhead_us + kv * 0.3 / m.pcie_line_bw;
            for method in Method::ALL {
                let tc = if method == Method::Hgca { t_cpu_hgca } else { t_cpu };
                let e = trace::build_step(method, &m, t_attn, tc, t_io, 8);
                println!("== {} ==", method.label());
                println!("{}", trace::render_gantt(&e, 72));
            }
        }
        "tab1" => {
            scoutattention::studies::tab1_query_similarity(cfg.seed, &mut std::io::stdout())?;
        }
        "drift" => {
            let steps = args.get_usize("steps", 48)?;
            scoutattention::studies::fig6_drift(&cfg, steps, &mut std::io::stdout())?;
        }
        "warmup" => {
            let stack = Stack::load(&cfg)?;
            stack.rt.warmup()?;
            println!(
                "compiled {} artifacts for {}",
                stack.rt.manifest.entries.len(),
                cfg.preset
            );
        }
        "dump-config" => println!("{}", cfg.to_json().to_string()),
        other => anyhow::bail!("unknown command {other:?}\n{USAGE}"),
    }
    Ok(())
}
