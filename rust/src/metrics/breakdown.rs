//! Per-phase latency breakdown (Fig. 11's stacked bars).


/// Where a unit of wall-clock time went during a decode step.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Phase {
    /// GPU busy: attention kernels.
    GpuAttention,
    /// GPU busy: everything else in the layer (QKV, FFN, norm, head).
    GpuOther,
    /// GPU stalled waiting on CPU attention or PCIe transfers ("idle" in
    /// Fig. 11).
    Idle,
    /// Scheduler/bookkeeping on the critical path.
    Scheduler,
}

impl Phase {
    pub const ALL: [Phase; 4] =
        [Phase::GpuAttention, Phase::GpuOther, Phase::Idle, Phase::Scheduler];

    pub fn label(&self) -> &'static str {
        match self {
            Phase::GpuAttention => "attention",
            Phase::GpuOther => "other-compute",
            Phase::Idle => "idle",
            Phase::Scheduler => "scheduler",
        }
    }
}

/// Accumulated time per phase (microseconds).
#[derive(Debug, Clone, Default)]
pub struct PhaseBreakdown {
    pub gpu_attention_us: f64,
    pub gpu_other_us: f64,
    pub idle_us: f64,
    pub scheduler_us: f64,
}

impl PhaseBreakdown {
    pub fn add(&mut self, phase: Phase, us: f64) {
        debug_assert!(us >= 0.0, "negative phase time {us}");
        match phase {
            Phase::GpuAttention => self.gpu_attention_us += us,
            Phase::GpuOther => self.gpu_other_us += us,
            Phase::Idle => self.idle_us += us,
            Phase::Scheduler => self.scheduler_us += us,
        }
    }

    pub fn get(&self, phase: Phase) -> f64 {
        match phase {
            Phase::GpuAttention => self.gpu_attention_us,
            Phase::GpuOther => self.gpu_other_us,
            Phase::Idle => self.idle_us,
            Phase::Scheduler => self.scheduler_us,
        }
    }

    pub fn total_us(&self) -> f64 {
        self.gpu_attention_us + self.gpu_other_us + self.idle_us + self.scheduler_us
    }

    /// Fig. 11's headline number: fraction of end-to-end time the GPU
    /// spends stalled.
    pub fn idle_fraction(&self) -> f64 {
        let t = self.total_us();
        if t == 0.0 { 0.0 } else { self.idle_us / t }
    }

    pub fn merge(&mut self, other: &PhaseBreakdown) {
        self.gpu_attention_us += other.gpu_attention_us;
        self.gpu_other_us += other.gpu_other_us;
        self.idle_us += other.idle_us;
        self.scheduler_us += other.scheduler_us;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn idle_fraction() {
        let mut b = PhaseBreakdown::default();
        b.add(Phase::GpuAttention, 30.0);
        b.add(Phase::GpuOther, 10.0);
        b.add(Phase::Idle, 60.0);
        assert!((b.idle_fraction() - 0.6).abs() < 1e-9);
        assert_eq!(b.total_us(), 100.0);
    }

    #[test]
    fn merge_sums() {
        let mut a = PhaseBreakdown::default();
        a.add(Phase::Idle, 1.0);
        let mut b = PhaseBreakdown::default();
        b.add(Phase::Idle, 2.0);
        b.add(Phase::Scheduler, 3.0);
        a.merge(&b);
        assert_eq!(a.idle_us, 3.0);
        assert_eq!(a.scheduler_us, 3.0);
    }
}
