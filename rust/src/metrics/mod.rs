//! Metrics substrate: counters, latency histograms, phase breakdowns.
//!
//! Everything the paper reports — decode throughput, GPU idle fraction,
//! CPU compute ratio, latency breakdown (Fig. 11) — is assembled from
//! these primitives by the coordinator and the simulator.

mod breakdown;
mod histogram;

pub use breakdown::{Phase, PhaseBreakdown};
pub use histogram::Histogram;

use std::collections::HashMap;
use std::time::Duration;

use std::sync::Mutex;

/// Named execution counters (per-artifact call counts + cumulative time).
#[derive(Default)]
pub struct Counters {
    inner: Mutex<HashMap<String, (u64, Duration)>>,
}

impl Counters {
    pub fn record_exec(&self, name: &str, dt: Duration) {
        let mut g = self.inner.lock().unwrap();
        let e = g.entry(name.to_string()).or_insert((0, Duration::ZERO));
        e.0 += 1;
        e.1 += dt;
    }

    /// (calls, total time) for one name.
    pub fn get(&self, name: &str) -> (u64, Duration) {
        self.inner
            .lock().unwrap()
            .get(name)
            .copied()
            .unwrap_or((0, Duration::ZERO))
    }

    /// Snapshot sorted by cumulative time, descending.
    pub fn snapshot(&self) -> Vec<(String, u64, Duration)> {
        let mut v: Vec<_> = self
            .inner
            .lock().unwrap()
            .iter()
            .map(|(k, (n, d))| (k.clone(), *n, *d))
            .collect();
        v.sort_by(|a, b| b.2.cmp(&a.2));
        v
    }

    pub fn reset(&self) {
        self.inner.lock().unwrap().clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let c = Counters::default();
        c.record_exec("a", Duration::from_millis(2));
        c.record_exec("a", Duration::from_millis(3));
        c.record_exec("b", Duration::from_millis(1));
        let (n, d) = c.get("a");
        assert_eq!(n, 2);
        assert_eq!(d, Duration::from_millis(5));
        assert_eq!(c.snapshot()[0].0, "a");
    }
}
