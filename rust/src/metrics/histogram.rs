//! Fixed-bucket latency histogram (log2 buckets, lock-free-ish simplicity).

/// Log-bucketed histogram over positive f64 samples (latencies in us,
/// ratios, sizes). Tracks count/sum/min/max exactly and quantiles
/// approximately (bucket midpoint).
#[derive(Debug, Clone)]
pub struct Histogram {
    buckets: Vec<u64>,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
    /// left edge of bucket 0
    base: f64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self { buckets: vec![0; 64], count: 0, sum: 0.0, min: f64::INFINITY, max: 0.0, base: 1e-3 }
    }

    pub fn record(&mut self, v: f64) {
        let v = v.max(0.0);
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
        let idx = if v <= self.base {
            0
        } else {
            ((v / self.base).log2().floor() as usize).min(self.buckets.len() - 1)
        };
        self.buckets[idx] += 1;
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 { 0.0 } else { self.sum / self.count as f64 }
    }

    pub fn min(&self) -> f64 {
        if self.count == 0 { 0.0 } else { self.min }
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Fold another histogram into this one (pool-level aggregation of
    /// per-replica latency series). Both sides share the fixed log2
    /// bucket layout, so merging is exact at bucket granularity.
    pub fn merge(&mut self, other: &Histogram) {
        debug_assert_eq!(self.buckets.len(), other.buckets.len());
        debug_assert_eq!(self.base, other.base);
        for (b, o) in self.buckets.iter_mut().zip(&other.buckets) {
            *b += o;
        }
        self.count += other.count;
        self.sum += other.sum;
        if other.count > 0 {
            self.min = self.min.min(other.min);
            self.max = self.max.max(other.max);
        }
    }

    /// Approximate quantile (bucket geometric midpoint).
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64;
        let mut seen = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target && c > 0 {
                let lo = self.base * 2f64.powi(i as i32);
                return (lo * (lo * 2.0)).sqrt().clamp(self.min, self.max);
            }
        }
        self.max
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_stats() {
        let mut h = Histogram::new();
        for v in [1.0, 2.0, 3.0, 4.0] {
            h.record(v);
        }
        assert_eq!(h.count(), 4);
        assert!((h.mean() - 2.5).abs() < 1e-9);
        assert_eq!(h.min(), 1.0);
        assert_eq!(h.max(), 4.0);
    }

    #[test]
    fn quantiles_ordered() {
        let mut h = Histogram::new();
        for i in 1..=1000 {
            h.record(i as f64);
        }
        let p50 = h.quantile(0.5);
        let p99 = h.quantile(0.99);
        assert!(p50 <= p99);
        assert!(p50 > 100.0 && p50 < 1000.0, "p50={p50}");
    }

    #[test]
    fn merge_combines_counts_and_extremes() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        a.record(1.0);
        a.record(2.0);
        b.record(100.0);
        a.merge(&b);
        assert_eq!(a.count(), 3);
        assert_eq!(a.min(), 1.0);
        assert_eq!(a.max(), 100.0);
        assert!((a.sum() - 103.0).abs() < 1e-9);
        // merging an empty histogram is a no-op
        let before = a.count();
        a.merge(&Histogram::new());
        assert_eq!(a.count(), before);
    }

    #[test]
    fn empty_histogram_is_zero() {
        let h = Histogram::new();
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.quantile(0.5), 0.0);
    }
}
