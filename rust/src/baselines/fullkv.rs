//! FullKV baseline: fused dense decode step (also the accuracy oracle).

use std::sync::Arc;

use crate::coordinator::{gather, Batch, DecodeScheduler, StepStats};
use crate::engines::{GpuEngine, NativeEngine};
use crate::tensor::Tensor;

pub struct FullKvScheduler {
    pub gpu: Arc<GpuEngine>,
    pub native: Arc<NativeEngine>,
    /// Prompt tokens per resumable prefill chunk (see
    /// `coordinator::prefill`).
    pub prefill_chunk: usize,
}

impl FullKvScheduler {
    pub fn new(gpu: Arc<GpuEngine>, native: Arc<NativeEngine>) -> Self {
        Self { gpu, native, prefill_chunk: crate::coordinator::DEFAULT_PREFILL_CHUNK }
    }

    fn step_chunk(
        &mut self,
        seqs: &mut [crate::coordinator::SeqState],
        stats: &mut StepStats,
    ) -> crate::Result<()> {
        let spec = self.gpu.spec.clone();
        let (b, s_max, l) = (spec.batch, spec.max_seq, spec.n_layers);
        let w = spec.n_kv_heads * spec.head_dim;
        let n = seqs.len();

        let toks: Vec<u32> =
            (0..b).map(|s| if s < n { seqs[s].last_tok } else { 0 }).collect();
        let mut x = self.gpu.embed_tokens(&toks);
        for s in n..b {
            x.rows_mut(s, 1).fill(0.0);
        }
        let pos: Vec<i32> = (0..b).map(|s| if s < n { seqs[s].pos() } else { 0 }).collect();

        // Assemble the dense cache operands [L, B, S, Hkv, D].
        let mut kc = Tensor::zeros(&[l, b, s_max, spec.n_kv_heads, spec.head_dim]);
        let mut vc = Tensor::zeros(&[l, b, s_max, spec.n_kv_heads, spec.head_dim]);
        let seq_w = s_max * w;
        for (s, seq) in seqs.iter().enumerate() {
            let len = seq.cache.len();
            for layer in 0..l {
                // [len, Hkv, D] prefix of the layer, walked block by
                // block (per-layer shard read lock only) — blocks are no
                // longer one contiguous slab under refcounted storage.
                if len > 0 {
                    let view = seq.cache.layer(layer);
                    let off = (layer * b + s) * seq_w;
                    view.copy_rows_into(
                        0,
                        len,
                        &mut kc.data_mut()[off..off + len * w],
                        &mut vc.data_mut()[off..off + len * w],
                    );
                }
                stats.layers[layer].dense_tokens += len + 1;
            }
        }

        let (logits, kn, vn) = self.gpu.decode_full(&x, &kc, &vc, &pos)?;
        // kn/vn: [L, B, Hkv, D] -> per-layer tensors
        let mut k_news = Vec::with_capacity(l);
        let mut v_news = Vec::with_capacity(l);
        for layer in 0..l {
            k_news.push(Tensor::from_vec(
                &[b, spec.n_kv_heads, spec.head_dim],
                kn.rows(layer, 1).to_vec(),
            ));
            v_news.push(Tensor::from_vec(
                &[b, spec.n_kv_heads, spec.head_dim],
                vn.rows(layer, 1).to_vec(),
            ));
        }
        gather::sample_and_append(&mut seqs[..n], &logits, &k_news, &v_news, w);
        Ok(())
    }
}

impl DecodeScheduler for FullKvScheduler {
    // Dense attention ignores residency, but shares the admission path
    // so every method decodes from identical prefill state.
    fn begin_prefill(
        &self,
        req: &crate::coordinator::RequestSpec,
        budget_blocks: usize,
    ) -> crate::Result<crate::coordinator::PrefillState> {
        crate::coordinator::PrefillState::begin(
            &self.gpu.spec,
            req,
            budget_blocks,
            self.prefill_chunk,
        )
    }

    fn prefill_step(&mut self, st: &mut crate::coordinator::PrefillState) -> crate::Result<bool> {
        st.advance(&self.gpu)
    }

    fn finish_prefill(
        &mut self,
        st: crate::coordinator::PrefillState,
    ) -> crate::Result<crate::coordinator::SeqState> {
        st.finish(
            &self.native,
            crate::coordinator::PrefillParams {
                pin_sink: true,
                pin_recent: 1,
                recall_countdowns: vec![usize::MAX; self.gpu.spec.n_layers],
                head_groups: 1,
            },
        )
    }

    fn step(&mut self, batch: &mut Batch) -> crate::Result<StepStats> {
        let t0 = std::time::Instant::now();
        let spec = self.gpu.spec.clone();
        let mut stats = StepStats::new(spec.n_layers, batch.live(), false);
        let tile = spec.batch;
        let total = batch.seqs.len();
        let mut start = 0;
        while start < total {
            let end = (start + tile).min(total);
            self.step_chunk(&mut batch.seqs[start..end], &mut stats)?;
            start = end;
        }
        stats.wall_us = t0.elapsed().as_micros() as u64;
        Ok(stats)
    }

    fn name(&self) -> &'static str {
        "FullKV"
    }
}
