//! Baseline schedulers from the paper's evaluation (§4.1), implemented on
//! the same engines, cache, and stats plumbing as ScoutAttention so every
//! comparison is apples-to-apples:
//!
//! - [`FullKvScheduler`]  — vanilla dense attention, whole cache "on GPU"
//!   (the fused `decode_full` artifact).
//! - [`InfinigenScheduler`] — recall-based offloading: speculated top-k
//!   blocks are prefetched to the GPU one layer ahead (predicted query)
//!   and *all* attention runs on the GPU; every non-resident selected
//!   block costs a synchronous PCIe transfer that the timing plane prices
//!   against the one-layer window.
//! - [`HgcaScheduler`]    — co-attention: a recent sliding window stays
//!   on the GPU, the CPU computes sparse attention over the offloaded
//!   rest with the *real* query in parallel with the same layer — so the
//!   GPU waits for the slower CPU every layer (the 57% idle of Fig. 3).

mod fullkv;
mod hgca;
mod infinigen;

pub use fullkv::FullKvScheduler;
pub use hgca::HgcaScheduler;
pub use infinigen::InfinigenScheduler;
