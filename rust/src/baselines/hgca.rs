//! HGCA-style hybrid GPU-CPU co-attention.
//!
//! A recent sliding window (plus the sink block) stays on the GPU; the
//! CPU computes sparse attention over the offloaded remainder with the
//! *real* query, in parallel with the same layer's GPU work. Because the
//! real query only exists after the layer's QKV, the CPU window is just
//! one layer's attention slot — with the CPU ~20x slower, the GPU waits
//! (the 57% idle of Figs. 3/11). Numerically the CPU side here selects
//! top-k offloaded blocks by digest score, a faithful stand-in for
//! HGCA's moving-average-weight sparsification on the same budget.

use std::sync::Arc;

use crate::coordinator::{admission, gather, Batch, DecodeScheduler, SeqState, StepStats};
use crate::engines::gpu::BatchPartial;
use crate::engines::{GpuEngine, NativeEngine};
use crate::sparse::{score_blocks_slabs, select_topk};

pub struct HgcaScheduler {
    pub gpu: Arc<GpuEngine>,
    pub native: Arc<NativeEngine>,
    /// Complete blocks kept on the GPU as the sliding window (HGCA keeps
    /// ~25% of tokens; configured as blocks out of the k_blocks budget).
    pub window_blocks: usize,
    /// Prompt tokens per resumable prefill chunk.
    pub prefill_chunk: usize,
}

impl HgcaScheduler {
    pub fn new(gpu: Arc<GpuEngine>, native: Arc<NativeEngine>) -> Self {
        let window_blocks = (gpu.spec.k_blocks / 4).max(1);
        Self {
            gpu,
            native,
            window_blocks,
            prefill_chunk: crate::coordinator::DEFAULT_PREFILL_CHUNK,
        }
    }

    pub fn prefill_request(
        &mut self,
        batch: &mut Batch,
        req: &crate::coordinator::RequestSpec,
    ) -> crate::Result<()> {
        let spec = self.gpu.spec.clone();
        admission::prefill_request(
            &self.gpu,
            &self.native,
            batch,
            req,
            true,
            self.window_blocks,
            vec![usize::MAX; spec.n_layers],
            self.prefill_chunk,
            1,
        )
    }

    /// GPU window: sink + most recent `window_blocks` complete blocks.
    fn window(&self, full_blocks: usize) -> Vec<usize> {
        admission::pins(true, self.window_blocks, full_blocks)
    }

    fn step_chunk(&mut self, seqs: &mut [SeqState], stats: &mut StepStats) -> crate::Result<()> {
        let spec = self.gpu.spec.clone();
        let (b, l) = (spec.batch, spec.n_layers);
        let (hq, hkv, d) = (spec.n_q_heads, spec.n_kv_heads, spec.head_dim);
        let n = seqs.len();
        let toks: Vec<u32> =
            (0..b).map(|s| if s < n { seqs[s].last_tok } else { 0 }).collect();
        let mut x = self.gpu.embed_tokens(&toks);
        for s in n..b {
            x.rows_mut(s, 1).fill(0.0);
        }
        let pos: Vec<i32> = (0..b).map(|s| if s < n { seqs[s].pos() } else { 0 }).collect();

        let mut k_news = Vec::with_capacity(l);
        let mut v_news = Vec::with_capacity(l);
        for i in 0..l {
            let (q, k_new, v_new) = self.gpu.pre_attn(&x, i, &pos)?;
            let q2 = q.clone().reshape(&[b, hq * d]);

            // CPU side: real-query top-k over offloaded blocks, same layer
            // (no pipelining possible — the real query just materialized).
            let mut cpu_bp = BatchPartial::empty(b, hq, d);
            let mut windows: Vec<Vec<usize>> = Vec::with_capacity(n);
            let nb = spec.n_blocks();
            for (s, seq) in seqs.iter_mut().enumerate() {
                let full = seq.cache.full_blocks();
                let window = self.window(full);
                let qrow = &q2.rows(s, 1)[..hq * d];
                let view = seq.cache.layer(i);
                let scores = {
                    let (lo, hi) = view.digests();
                    score_blocks_slabs(qrow, lo, hi, nb, full, hq, hkv, d)
                };
                // offloaded = not in window; CPU budget = k_blocks - window
                let budget = spec.k_blocks.saturating_sub(window.len());
                let mut masked = scores.clone();
                for &wblk in &window {
                    masked[wblk] = f32::NEG_INFINITY;
                }
                let sel = select_topk(&masked, budget, &[]);
                let partial = self.native.attend_blocks(qrow, &view, &sel.blocks);
                drop(view);
                cpu_bp.set_row(s, &partial);
                stats.layers[i].cpu_blocks += sel.blocks.len();
                stats.layers[i].gpu_blocks += window.len();
                stats.layers[i].selected_blocks += sel.blocks.len() + window.len();
                seq.scores_mut(i).clone_from(&scores);
                windows.push(window);
            }

            // GPU side: window + tail.
            let (ks, vs, ms) = gather::gather_block_lists(&self.gpu, seqs, i, |s, _| {
                windows[s].clone()
            });
            let p_gpu = self.gpu.sparse_attn(&q, &ks, &vs, &ms)?;
            let (kt, vt, mt) = gather::gather_tail(&self.gpu, seqs, i, &k_new, &v_new);
            let p_tail = self.gpu.tail_attn(&q, &kt, &vt, &mt)?;
            let merged = self.gpu.merge(&p_gpu, &p_tail)?;
            let merged = self.gpu.merge(&merged, &cpu_bp)?;
            x = self.gpu.post_attn(&x, &merged, i)?;
            k_news.push(k_new);
            v_news.push(v_new);
        }
        let logits = self.gpu.lm_head(&x)?;
        let w = spec.n_kv_heads * spec.head_dim;
        gather::sample_and_append(&mut seqs[..n], &logits, &k_news, &v_news, w);
        Ok(())
    }
}

impl DecodeScheduler for HgcaScheduler {
    fn begin_prefill(
        &self,
        req: &crate::coordinator::RequestSpec,
        budget_blocks: usize,
    ) -> crate::Result<crate::coordinator::PrefillState> {
        crate::coordinator::PrefillState::begin(
            &self.gpu.spec,
            req,
            budget_blocks,
            self.prefill_chunk,
        )
    }

    fn prefill_step(&mut self, st: &mut crate::coordinator::PrefillState) -> crate::Result<bool> {
        st.advance(&self.gpu)
    }

    fn finish_prefill(
        &mut self,
        st: crate::coordinator::PrefillState,
    ) -> crate::Result<SeqState> {
        st.finish(
            &self.native,
            crate::coordinator::PrefillParams {
                pin_sink: true,
                pin_recent: self.window_blocks,
                recall_countdowns: vec![usize::MAX; self.gpu.spec.n_layers],
                head_groups: 1,
            },
        )
    }

    fn step(&mut self, batch: &mut Batch) -> crate::Result<StepStats> {
        let t0 = std::time::Instant::now();
        let spec = self.gpu.spec.clone();
        let mut stats = StepStats::new(spec.n_layers, batch.live(), false);
        let tile = spec.batch;
        let total = batch.seqs.len();
        let mut start = 0;
        while start < total {
            let end = (start + tile).min(total);
            self.step_chunk(&mut batch.seqs[start..end], &mut stats)?;
            start = end;
        }
        stats.wall_us = t0.elapsed().as_micros() as u64;
        Ok(stats)
    }

    fn name(&self) -> &'static str {
        "HGCA"
    }
}
