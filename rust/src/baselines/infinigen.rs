//! InfiniGen-style recall-based offloading.
//!
//! Selection uses the predicted query one layer ahead (InfiniGen's own
//! speculation mechanism, which ScoutAttention §3.3 credits); the
//! speculated blocks are *fetched to the GPU* and all attention runs
//! there. Numerically this is predicted-query top-k attention on the GPU;
//! in the timing plane every selected-but-not-resident block is a
//! synchronous PCIe transfer with only a one-layer window to hide in —
//! the source of the 61% idle time in Figs. 3/11.

use std::sync::Arc;

use crate::coordinator::{admission, gather, Batch, DecodeScheduler, SeqState, StepStats};
use crate::engines::{GpuEngine, NativeEngine};
use crate::sparse::{score_blocks_slabs, select_topk};
use crate::tensor::Tensor;

pub struct InfinigenScheduler {
    pub gpu: Arc<GpuEngine>,
    pub native: Arc<NativeEngine>,
    /// Keep the sink block pinned like the other methods (fair config).
    pub pin_sink: bool,
    pub pin_recent: usize,
    /// Prompt tokens per resumable prefill chunk.
    pub prefill_chunk: usize,
}

impl InfinigenScheduler {
    pub fn new(gpu: Arc<GpuEngine>, native: Arc<NativeEngine>) -> Self {
        Self {
            gpu,
            native,
            pin_sink: true,
            pin_recent: 1,
            prefill_chunk: crate::coordinator::DEFAULT_PREFILL_CHUNK,
        }
    }

    pub fn prefill_request(
        &mut self,
        batch: &mut Batch,
        req: &crate::coordinator::RequestSpec,
    ) -> crate::Result<()> {
        let spec = self.gpu.spec.clone();
        admission::prefill_request(
            &self.gpu,
            &self.native,
            batch,
            req,
            self.pin_sink,
            self.pin_recent,
            vec![usize::MAX; spec.n_layers], // no periodic recall
            self.prefill_chunk,
            1,
        )
    }

    /// Select for `layer` with query rows `q` (`[B, Hq*D]`); the selected
    /// set is fetched (sync transfers for misses) and becomes resident.
    fn select_and_fetch(
        &self,
        seqs: &mut [SeqState],
        q: &Tensor,
        layer: usize,
        stats: &mut StepStats,
    ) {
        let spec = &self.gpu.spec;
        let (hq, hkv, d) = (spec.n_q_heads, spec.n_kv_heads, spec.head_dim);
        let nb = spec.n_blocks();
        for (s, seq) in seqs.iter_mut().enumerate() {
            let full = seq.cache.full_blocks();
            let qrow = &q.rows(s, 1)[..hq * d];
            let scores = {
                let view = seq.cache.layer(layer);
                let (lo, hi) = view.digests();
                score_blocks_slabs(qrow, lo, hi, nb, full, hq, hkv, d)
            };
            let pins = admission::pins(self.pin_sink, self.pin_recent, full);
            let sel = select_topk(&scores, spec.k_blocks, &pins);
            // blocks not already on the GPU must cross PCIe *now* (the
            // prefetch window is the previous layer only)
            let (_, misses) = seq.resident[layer].partition(&sel.blocks);
            stats.layers[layer].sync_transfer_blocks += misses.len();
            stats.layers[layer].gpu_blocks += sel.blocks.len();
            stats.layers[layer].selected_blocks += sel.blocks.len();
            seq.resident[layer].refresh(&sel.blocks);
            seq.selected[layer] = vec![sel.blocks];
            seq.scores_mut(layer).clone_from(&scores);
        }
    }

    fn step_chunk(&mut self, seqs: &mut [SeqState], stats: &mut StepStats) -> crate::Result<()> {
        let spec = self.gpu.spec.clone();
        let (b, l) = (spec.batch, spec.n_layers);
        let n = seqs.len();
        let toks: Vec<u32> =
            (0..b).map(|s| if s < n { seqs[s].last_tok } else { 0 }).collect();
        let mut x = self.gpu.embed_tokens(&toks);
        for s in n..b {
            x.rows_mut(s, 1).fill(0.0);
        }
        let pos: Vec<i32> = (0..b).map(|s| if s < n { seqs[s].pos() } else { 0 }).collect();

        // layer-0 prefetch at step start (exact query).
        let q0 = self.gpu.qpred(&x, 0, &pos)?;
        self.select_and_fetch(seqs, &q0, 0, stats);

        let mut k_news = Vec::with_capacity(l);
        let mut v_news = Vec::with_capacity(l);
        for i in 0..l {
            // speculate layer i+1's important blocks from layer i's input
            if i + 1 < l {
                let qp = self.gpu.qpred(&x, i + 1, &pos)?;
                self.select_and_fetch(seqs, &qp, i + 1, stats);
            }
            let (q, k_new, v_new) = self.gpu.pre_attn(&x, i, &pos)?;
            let (ks, vs, ms) =
                gather::gather_block_lists(&self.gpu, seqs, i, |_, seq| seq.selected[i].concat());
            let p_gpu = self.gpu.sparse_attn(&q, &ks, &vs, &ms)?;
            let (kt, vt, mt) = gather::gather_tail(&self.gpu, seqs, i, &k_new, &v_new);
            let p_tail = self.gpu.tail_attn(&q, &kt, &vt, &mt)?;
            let merged = self.gpu.merge(&p_gpu, &p_tail)?;
            x = self.gpu.post_attn(&x, &merged, i)?;
            k_news.push(k_new);
            v_news.push(v_new);
        }
        let logits = self.gpu.lm_head(&x)?;
        let w = spec.n_kv_heads * spec.head_dim;
        gather::sample_and_append(&mut seqs[..n], &logits, &k_news, &v_news, w);
        Ok(())
    }
}

impl DecodeScheduler for InfinigenScheduler {
    fn begin_prefill(
        &self,
        req: &crate::coordinator::RequestSpec,
        budget_blocks: usize,
    ) -> crate::Result<crate::coordinator::PrefillState> {
        crate::coordinator::PrefillState::begin(
            &self.gpu.spec,
            req,
            budget_blocks,
            self.prefill_chunk,
        )
    }

    fn prefill_step(&mut self, st: &mut crate::coordinator::PrefillState) -> crate::Result<bool> {
        st.advance(&self.gpu)
    }

    fn finish_prefill(
        &mut self,
        st: crate::coordinator::PrefillState,
    ) -> crate::Result<SeqState> {
        st.finish(
            &self.native,
            crate::coordinator::PrefillParams {
                pin_sink: self.pin_sink,
                pin_recent: self.pin_recent,
                recall_countdowns: vec![usize::MAX; self.gpu.spec.n_layers],
                head_groups: 1,
            },
        )
    }

    fn step(&mut self, batch: &mut Batch) -> crate::Result<StepStats> {
        let t0 = std::time::Instant::now();
        let spec = self.gpu.spec.clone();
        let mut stats = StepStats::new(spec.n_layers, batch.live(), true);
        let tile = spec.batch;
        let total = batch.seqs.len();
        let mut start = 0;
        while start < total {
            let end = (start + tile).min(total);
            self.step_chunk(&mut batch.seqs[start..end], &mut stats)?;
            start = end;
        }
        stats.wall_us = t0.elapsed().as_micros() as u64;
        Ok(stats)
    }

    fn name(&self) -> &'static str {
        "InfiniGen"
    }
}
