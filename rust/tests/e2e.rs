//! End-to-end scheduler tests on the test-tiny stack (interpreter
//! backend by default — no artifacts required): all four methods decode
//! the same workload; Scout output stays close to the FullKV oracle;
//! schedule stats behave per the paper's mechanisms.

mod common;

use scoutattention::config::{Method, RecallPolicy};
use scoutattention::harness::{self, Stack};
use scoutattention::workload::{LengthMix, WorkloadGen};

fn requests(stack: &Stack, n: usize, prompt: usize, new_tokens: usize) -> Vec<scoutattention::coordinator::RequestSpec> {
    let spec = stack.gpu.spec.clone();
    let mut gen = WorkloadGen::new(7, spec.vocab, LengthMix::Fixed(prompt), new_tokens);
    gen.take(n)
}

#[test]
fn all_methods_decode_and_scout_tracks_oracle() {
    let stack = common::stack();
    let spec = stack.gpu.spec.clone();
    let prompt = spec.block_size * 8; // 8 full blocks > k_blocks=4 budget
    let reqs = requests(&stack, 3, prompt, 12);

    let oracle = harness::run_method(&stack, Method::FullKv, reqs.clone(), 1000, None).unwrap();
    assert_eq!(oracle.outputs.len(), 3);
    for o in &oracle.outputs {
        assert_eq!(o.generated.len(), 12, "oracle finished");
    }

    for method in [Method::Scout, Method::Infinigen, Method::Hgca] {
        let run = harness::run_method(&stack, method, reqs.clone(), 1000, None).unwrap();
        assert_eq!(run.outputs.len(), 3, "{method:?} finished all requests");
        let agree = harness::token_agreement(&run, &oracle);
        // sparse methods on a tiny random-weight model: demand substantial
        // agreement with dense attention (scout/infinigen select with
        // digest top-k; hgca keeps a window)
        assert!(
            agree >= 0.5,
            "{method:?} token agreement vs FullKV too low: {agree}"
        );
        // sparse methods must actually offload: scout & hgca have CPU work
        if method != Method::Infinigen {
            assert!(
                run.stats.iter().any(|s| s.cpu_ratio() > 0.0),
                "{method:?} never used the CPU side"
            );
        }
    }
}

#[test]
fn scout_beats_selection_off_in_agreement() {
    // The needle of the design: predicted-query selection must track the
    // oracle better than a static (no-selection, window-only) policy. We
    // proxy the latter with HGCA at the same budget.
    let stack = common::stack();
    let spec = stack.gpu.spec.clone();
    let prompt = spec.block_size * 10;
    let reqs = requests(&stack, 2, prompt, 16);
    let oracle = harness::run_method(&stack, Method::FullKv, reqs.clone(), 1000, None).unwrap();
    let scout = harness::run_method(&stack, Method::Scout, reqs.clone(), 1000, None).unwrap();
    let hgca = harness::run_method(&stack, Method::Hgca, reqs, 1000, None).unwrap();
    let a_scout = harness::token_agreement(&scout, &oracle);
    let a_hgca = harness::token_agreement(&hgca, &oracle);
    assert!(
        a_scout + 1e-9 >= a_hgca,
        "scout {a_scout} should track the oracle at least as well as window-only {a_hgca}"
    );
}

#[test]
fn periodic_recall_reduces_cpu_ratio() {
    let stack = common::stack();
    let spec = stack.gpu.spec.clone();
    let prompt = spec.block_size * 10;
    let reqs = requests(&stack, 2, prompt, 24);

    // no recall: drift accumulates
    let mut cfg_a = stack.cfg.clone();
    cfg_a.scout.recall = RecallPolicy::Disabled;
    let stack_a = Stack { cfg: cfg_a, rt: stack.rt.clone(), gpu: stack.gpu.clone(), native: stack.native.clone() };
    let run_a = harness::run_method(&stack_a, Method::Scout, reqs.clone(), 1000, None).unwrap();

    // aggressive fixed recall
    let mut cfg_b = stack.cfg.clone();
    cfg_b.scout.recall = RecallPolicy::Fixed { interval: 2 };
    let stack_b = Stack { cfg: cfg_b, rt: stack.rt.clone(), gpu: stack.gpu.clone(), native: stack.native.clone() };
    let run_b = harness::run_method(&stack_b, Method::Scout, reqs, 1000, None).unwrap();

    let recall_blocks: usize = run_b.stats.iter().map(|s| s.recall_blocks()).sum();
    assert!(recall_blocks > 0, "recall must fire");
    assert!(
        run_b.mean_cpu_ratio() <= run_a.mean_cpu_ratio() + 1e-9,
        "recall should not increase CPU load: {} vs {}",
        run_b.mean_cpu_ratio(),
        run_a.mean_cpu_ratio()
    );
    let no_recall: usize = run_a.stats.iter().map(|s| s.recall_blocks()).sum();
    assert_eq!(no_recall, 0, "disabled policy must never recall");
}

#[test]
fn ablation_arms_run_and_record_modes() {
    let stack = common::stack();
    let spec = stack.gpu.spec.clone();
    let prompt = spec.block_size * 6;
    let reqs = requests(&stack, 2, prompt, 6);

    let mut cfg = stack.cfg.clone();
    cfg.scout.layer_ahead = false;
    let stack_nopc =
        Stack { cfg, rt: stack.rt.clone(), gpu: stack.gpu.clone(), native: stack.native.clone() };
    let run = harness::run_method(&stack_nopc, Method::Scout, reqs.clone(), 1000, None).unwrap();
    assert!(run.stats.iter().all(|s| !s.layer_ahead), "-PC arm must be serial");
    let run_pc = harness::run_method(&stack, Method::Scout, reqs, 1000, None).unwrap();
    assert!(run_pc.stats.iter().all(|s| s.layer_ahead), "default is pipelined");
    // same numbers either way within fp tolerance? Not exactly: -PC uses
    // the REAL query for CPU-side selection, so outputs may differ — but
    // both must complete every request.
    assert_eq!(run.outputs.len(), 2);
    assert_eq!(run_pc.outputs.len(), 2);
}

#[test]
fn continuous_batching_admits_beyond_tile() {
    let stack = common::stack();
    let spec = stack.gpu.spec.clone();
    // 2x the batch tile, with an admission cap below the request count:
    // forces chunked steps + real queueing between steps.
    let n_req = spec.batch * 2 + 1;
    let mut cfg = stack.cfg.clone();
    cfg.server.max_batch = 2;
    let stack_capped = Stack {
        cfg,
        rt: stack.rt.clone(),
        gpu: stack.gpu.clone(),
        native: stack.native.clone(),
    };
    let reqs = requests(&stack, n_req, spec.block_size * 4, 4);
    let run = harness::run_method(&stack_capped, Method::Scout, reqs, 2000, None).unwrap();
    assert_eq!(run.outputs.len(), n_req);
    for o in &run.outputs {
        assert_eq!(o.generated.len(), 4);
    }
    assert_eq!(run.total_admitted(), n_req, "every request admitted exactly once");
    assert!(run.peak_queue_depth() > 0, "admission cap must make queueing observable");
}

#[test]
fn profiled_recall_intervals_derive_from_measured_series() {
    let stack = common::stack();
    let spec = stack.gpu.spec.clone();
    let reqs = requests(&stack, 2, spec.block_size * 10, 16);
    let mut cfg = stack.cfg.clone();
    cfg.scout.recall = RecallPolicy::Disabled;
    let stack_a = Stack { cfg, rt: stack.rt.clone(), gpu: stack.gpu.clone(), native: stack.native.clone() };
    let run = harness::run_method(&stack_a, Method::Scout, reqs.clone(), 1000, None).unwrap();
    let series = run.cpu_ratio_series(spec.n_layers);
    assert_eq!(series.series.len(), spec.n_layers);
    let intervals = series.intervals(stack.cfg.scout.beta, 32);
    assert!(intervals.iter().all(|&i| (1..=32).contains(&i)));
    // feeding the profile back in must produce a working scheduler
    let run2 = harness::run_method(&stack, Method::Scout, reqs, 1000, Some(&series)).unwrap();
    assert_eq!(run2.outputs.len(), 2);
}
