//! Schedule-exploring model tests for the repo's core concurrent
//! protocols, driven by the in-tree [`sched`] permutation explorer (the
//! offline stand-in for `loom` — every sequentially-consistent
//! interleaving of the modeled steps is executed and checked).
//!
//! 1. **Double-buffered resident-set refresh** (kvcache::resident):
//!    driven against the *real* `ResidentSet` — a recall tick staging a
//!    re-rank concurrently with a decode step must never change the set
//!    visible to attention except at the commit boundary, and the
//!    committed set must always be one whole plan, never a blend.
//! 2. **Sharded-store length publication** (kvcache::store): the
//!    write-rows-then-Release-len protocol, modeled abstractly; the
//!    seeded publish-first reversal proves the explorer finds the torn
//!    read that the real store's Release/Acquire pair prevents.
//! 3. **Serve-pool handoff + cancellation lifecycle** (serve::pool): a
//!    request migrating prefill→decode while the client concurrently
//!    cancels must get exactly one terminal event and exactly one
//!    budget release on every schedule; the seeded drop-discipline bug
//!    (source never dropping its handoff sender) must be reported as a
//!    deadlock.
//! 4. **Prefix-pool publish/import/evict** (kvcache::prefix): a probe
//!    racing capacity eviction must either miss cleanly or acquire a
//!    block eviction can no longer touch; the two seeded bugs — evicting
//!    a held entry, and splitting the probe's lookup from its refcount
//!    bump — are both caught with their minimal counterexample
//!    schedules.
//! 5. **Supervisor crash recovery vs. client cancellation**
//!    (serve::pool): when the supervisor catches an engine panic and
//!    re-settles the dead engine's tracks, a client concurrently
//!    cancelling must still observe exactly one terminal event and
//!    exactly one budget release on every schedule — decode-stage
//!    tracks answer `ReplicaLost` immediately, prefill-stage tracks
//!    replay through the respawned engine with their reservation kept;
//!    the two seeded recovery bugs (answering a replayed request, and
//!    releasing a replayed request's reservation) are both caught.
//! 6. **Session-tier demotion vs. resume vs. cancel** (kvcache::tier):
//!    the plan-under-lock / spill-without-lock / commit-under-fresh-lock
//!    demotion protocol racing a resume that consumes the session (and a
//!    client cancel) must, on every schedule, answer the resuming
//!    request exactly once, never deallocate a block set the resumer
//!    still holds, and never leak the orphaned spill record; the two
//!    seeded bugs — a commit that skips the staleness check and frees
//!    held blocks, and one that forgets to free the orphaned record —
//!    are both caught.
//! 7. **Per-head-group stage/commit isolation** (kvcache::resident,
//!    `head_groups > 1`): driven against the real grouped `ResidentSet`
//!    — a recall tick restaging one head group concurrently with
//!    another group's stage/commit must never perturb the other group's
//!    visible set, and each group's committed set is always one whole
//!    plan of its *own* rankings, never a cross-group blend.
//!
//! [`sched`]: scoutattention::util::sched

use scoutattention::kvcache::ResidentSet;
use scoutattention::util::sched::{run, step, Explorer, Step};

// ---------------------------------------------------------------------
// Protocol 1: double-buffered ResidentSet stage/commit (real type).
// ---------------------------------------------------------------------

#[derive(Clone)]
struct RecallState {
    rs: ResidentSet,
    /// Visible set recorded by the decode thread *before* its commit.
    pre_commit_view: Option<Vec<usize>>,
    /// Blocks reported fetched by the commit.
    fetched: Option<usize>,
    /// Visible set recorded by the decode thread *after* its commit.
    post_commit_view: Option<Vec<usize>>,
}

fn visible(rs: &ResidentSet) -> Vec<usize> {
    rs.iter().collect()
}

/// A recall tick staging concurrently with a decode step's
/// observe→commit→observe never perturbs the pre-commit view, and the
/// post-commit view is exactly the staged plan iff the stage landed
/// before the commit — on every interleaving.
#[test]
fn staged_recall_is_invisible_until_commit_under_all_schedules() {
    let initial = {
        let mut rs = ResidentSet::new(16, 3);
        rs.refresh(&[0, 1, 2]);
        RecallState {
            rs,
            pre_commit_view: None,
            fetched: None,
            post_commit_view: None,
        }
    };

    let mut ex: Explorer<RecallState> = Explorer::new();
    // Recall thread: one asynchronous tick re-ranking to {0, 5, 6}.
    ex.thread(vec![run(|s: &mut RecallState| {
        let fetch = s.rs.stage(&[0, 5, 6]);
        assert_eq!(fetch, 2, "5 and 6 are the PCIe fetches");
    })]);
    // Decode thread: observe (attention partition), hit the commit
    // boundary, observe again.
    ex.thread(vec![
        run(|s: &mut RecallState| s.pre_commit_view = Some(visible(&s.rs))),
        run(|s: &mut RecallState| s.fetched = Some(s.rs.commit_staged())),
        run(|s: &mut RecallState| s.post_commit_view = Some(visible(&s.rs))),
    ]);
    ex.invariant(|s| {
        // Staging alone must never alter what attention can see.
        if let Some(v) = &s.pre_commit_view {
            if s.fetched.is_none() && *v != vec![0, 1, 2] {
                return Err(format!("pre-commit view perturbed: {v:?}"));
            }
        }
        Ok(())
    });
    ex.final_check(|s| {
        let fetched = s.fetched.unwrap();
        let post = s.post_commit_view.clone().unwrap();
        // The commit either saw the staged plan (stage ≤ commit in this
        // schedule) and flipped wholesale, or saw nothing and was a
        // no-op. `fetched` must agree with the view — a mismatch means
        // the timing plane counted I/O the numerics plane didn't get.
        match (fetched, post.as_slice()) {
            (2, [0, 5, 6]) | (0, [0, 1, 2]) => Ok(()),
            other => Err(format!("torn commit: {other:?}")),
        }
    });
    let stats = ex.explore(initial).expect("all schedules hold");
    // 1-step recall thread into a 3-step decode thread: 4 interleavings.
    assert_eq!(stats.schedules, 4);
}

/// Two recall ticks racing one commit: the visible set is always one
/// whole plan (initial, first ranking, or second ranking), never a
/// blend of two plans — restaging replaces, it does not merge.
#[test]
fn restaging_never_blends_plans_under_all_schedules() {
    let initial = {
        let mut rs = ResidentSet::new(16, 2);
        rs.refresh(&[0, 1]);
        RecallState {
            rs,
            pre_commit_view: None,
            fetched: None,
            post_commit_view: None,
        }
    };
    let mut ex: Explorer<RecallState> = Explorer::new();
    ex.thread(vec![
        run(|s: &mut RecallState| {
            s.rs.stage(&[2, 3]);
        }),
        run(|s: &mut RecallState| {
            s.rs.stage(&[0, 4]);
        }),
    ]);
    ex.thread(vec![run(|s: &mut RecallState| {
        s.fetched = Some(s.rs.commit_staged());
    })]);
    ex.invariant(|s| {
        let v = visible(&s.rs);
        match v.as_slice() {
            [0, 1] | [2, 3] | [0, 4] => Ok(()),
            blend => Err(format!("blended resident set {blend:?}")),
        }
    });
    let stats = ex.explore(initial).expect("all schedules hold");
    assert_eq!(stats.schedules, 3);
}

// ---------------------------------------------------------------------
// Protocol 2: sharded-store length publication (abstract model).
// ---------------------------------------------------------------------

/// Abstraction of `kvcache::store`'s decode-visibility protocol: row
/// payloads are written first, then `len` is published with a Release
/// store; readers Acquire-load `len` and touch only rows `< len`.
#[derive(Clone, Default)]
struct LenState {
    /// Rows whose K/V payload writes have completed.
    rows_written: usize,
    /// The published length (the Acquire/Release atomic in the real
    /// store).
    len: usize,
    /// Set when a reader dereferenced a row the writer had not filled.
    torn_read: bool,
}

fn reader_steps(ex: &mut Explorer<LenState>) {
    ex.thread(vec![run(|s: &mut LenState| {
        // One atomic model step = Acquire-load len, then read rows < len
        // (in the real store the Acquire edge makes those rows' payload
        // writes visible — under SC the model just checks the count).
        if s.len > s.rows_written {
            s.torn_read = true;
        }
    })]);
}

fn torn_read_invariant(ex: &mut Explorer<LenState>) {
    ex.invariant(|s| {
        if s.torn_read {
            Err(format!(
                "reader observed len {} with only {} rows written",
                s.len, s.rows_written
            ))
        } else {
            Ok(())
        }
    });
}

/// The real protocol (write rows, then publish len) holds on every
/// interleaving of a two-row append against a concurrent reader.
#[test]
fn write_then_publish_len_holds_under_all_schedules() {
    let mut ex: Explorer<LenState> = Explorer::new();
    ex.thread(vec![
        run(|s: &mut LenState| s.rows_written = 1),
        run(|s: &mut LenState| s.rows_written = 2),
        run(|s: &mut LenState| s.len = 2),
    ]);
    reader_steps(&mut ex);
    torn_read_invariant(&mut ex);
    let stats = ex.explore(LenState::default()).expect("protocol holds");
    assert_eq!(stats.schedules, 4);
}

/// Seeded reversal: publishing len before the payload writes (what the
/// store would do if `advance` stored `len` Relaxed-early, or stored it
/// before the row copies) is caught, with the minimal counterexample
/// schedule reported.
#[test]
fn publish_before_write_reversal_is_caught() {
    let mut ex: Explorer<LenState> = Explorer::new();
    ex.thread(vec![
        run(|s: &mut LenState| s.len = 2), // BUG: published first
        run(|s: &mut LenState| s.rows_written = 1),
        run(|s: &mut LenState| s.rows_written = 2),
    ]);
    reader_steps(&mut ex);
    torn_read_invariant(&mut ex);
    let v = ex.explore(LenState::default()).expect_err("reversal must be caught");
    assert_eq!(
        v.schedule,
        vec![0, 0, 1],
        "first counterexample in DFS order: publish, one row written, then the reader"
    );
    assert!(v.message.contains("len 2"), "{v}");
}

// ---------------------------------------------------------------------
// Protocol 3: serve-pool handoff + cancellation lifecycle.
// ---------------------------------------------------------------------

/// Where the request's track (events sender + budget reservation) lives.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum Loc {
    /// Tracked by the prefill-role source replica.
    Source,
    /// In flight on the handoff channel.
    Channel,
    /// Tracked by the decode-role destination replica.
    Dest,
    /// Terminated — track removed, client answered.
    Gone,
}

#[derive(Clone)]
struct HandoffState {
    loc: Loc,
    /// The shared `Arc<AtomicBool>` cancel flag (travels with the track).
    cancel: bool,
    /// Terminal events emitted to the client (must end at exactly 1).
    terminals: usize,
    /// Budget releases (must end at exactly 1 — double release corrupts
    /// the pool token budget; zero leaks it).
    releases: usize,
    /// Handoff senders still held by the source replica.
    sender_alive: bool,
}

fn handoff_initial() -> HandoffState {
    HandoffState {
        loc: Loc::Source,
        cancel: false,
        terminals: 0,
        releases: 0,
        sender_alive: true,
    }
}

fn lifecycle_invariants(ex: &mut Explorer<HandoffState>) {
    ex.invariant(|s| {
        if s.terminals > 1 {
            return Err("client answered twice".into());
        }
        if s.releases > 1 {
            return Err("budget reservation released twice".into());
        }
        if s.loc == Loc::Gone && s.terminals != s.releases {
            return Err(format!(
                "terminated with terminals {} != releases {}",
                s.terminals, s.releases
            ));
        }
        Ok(())
    });
    ex.final_check(|s| {
        if s.loc != Loc::Gone {
            return Err(format!("request stranded at {:?}", s.loc));
        }
        if s.terminals != 1 || s.releases != 1 {
            return Err(format!(
                "lifecycle ended with terminals {} releases {}",
                s.terminals, s.releases
            ));
        }
        Ok(())
    });
}

/// The full lifecycle — source checks cancel then hands off, dest
/// imports then checks cancel then finishes, client cancels at an
/// arbitrary point — yields exactly one terminal event and exactly one
/// budget release on EVERY schedule, and the source's sender drop lets
/// the blocked destination terminate (no deadlock in any interleaving).
#[test]
fn handoff_cancel_lifecycle_holds_under_all_schedules() {
    let mut ex: Explorer<HandoffState> = Explorer::new();

    // Client thread: raise the shared cancel flag (at any point).
    ex.thread(vec![run(|s: &mut HandoffState| s.cancel = true)]);

    // Source (prefill-role) replica: the pool.rs eviction sweep runs
    // before the routing step, so cancel-while-owned terminates here;
    // otherwise the track moves onto the channel. Either way the
    // replica then drops its handoff senders (drain discipline).
    ex.thread(vec![
        run(|s: &mut HandoffState| {
            if s.loc == Loc::Source {
                if s.cancel {
                    s.terminals += 1; // Cancelled
                    s.releases += 1;
                    s.loc = Loc::Gone;
                } else {
                    s.loc = Loc::Channel; // dispatch_handoff
                }
            }
        }),
        run(|s: &mut HandoffState| s.sender_alive = false),
    ]);

    // Destination (decode-role) replica: blocking recv on the handoff
    // channel — wakes for a message OR for the disconnect cascade; then
    // its own cancel sweep / completion path.
    ex.thread(vec![
        step(|s: &mut HandoffState| {
            if s.loc == Loc::Channel {
                s.loc = Loc::Dest; // import_handoff
                Step::Ran
            } else if !s.sender_alive {
                Step::Ran // recv -> Disconnected: wake, nothing to import
            } else {
                Step::Blocked // parked in recv
            }
        }),
        run(|s: &mut HandoffState| {
            if s.loc == Loc::Dest {
                // The cancel flag traveled with the track across the
                // channel: the dest's sweep observes the same flag the
                // client raised.
                s.terminals += 1; // Cancelled or Done
                s.releases += 1;
                s.loc = Loc::Gone;
            }
        }),
    ]);

    lifecycle_invariants(&mut ex);
    let stats = ex.explore(handoff_initial()).expect("lifecycle holds");
    assert!(stats.schedules > 1, "the race must actually branch");
}

// ---------------------------------------------------------------------
// Protocol 4: prefix-pool publish / import / evict (kvcache::prefix).
// ---------------------------------------------------------------------

/// Abstraction of one `PrefixPool` entry's lifecycle. `refs` models the
/// block `Arc`'s strong count (1 = the pool's own hold); the real pool
/// does lookup + clone atomically under its mutex, and eviction removes
/// an entry only when the pool's hold is the last one — in which case
/// removal really does deallocate the blocks, which is what `freed`
/// records.
#[derive(Clone, Default)]
struct PrefixState {
    /// Entry present in the pool map.
    resident: bool,
    /// Strong count of the entry's blocks (0 = never published).
    refs: usize,
    /// The blocks were deallocated (pool dropped the last hold).
    freed: bool,
    /// Importer outcome: None = not probed yet, Some(hit).
    imported: Option<bool>,
    /// Eviction removed an entry a live sequence still held.
    evicted_held: bool,
    /// Buggy split-probe's stale lookup result (seeded variant only).
    saw_hit: bool,
    /// Importer cloned from an entry eviction had already removed.
    stale_import: bool,
}

fn prefix_invariants(ex: &mut Explorer<PrefixState>) {
    ex.invariant(|s| {
        if s.evicted_held {
            return Err("evicted a block a live sequence still holds".into());
        }
        if s.stale_import {
            return Err("imported from an entry eviction already removed".into());
        }
        if s.imported == Some(true) && s.freed {
            return Err("imported blocks were deallocated".into());
        }
        if s.resident && s.refs == 0 {
            return Err("resident entry with no pool hold".into());
        }
        Ok(())
    });
}

/// The real protocol: publish installs the entry with the pool's hold;
/// probe (atomically, under the pool mutex) bumps the refcount on hit;
/// eviction removes the entry only when the pool's hold is the last
/// one. On every interleaving the importer either misses cleanly or
/// ends up holding blocks eviction can no longer free.
#[test]
fn prefix_publish_import_evict_holds_under_all_schedules() {
    let mut ex: Explorer<PrefixState> = Explorer::new();
    // Prefill thread: publish the chunk, then a later publish overflows
    // capacity and runs the eviction sweep with this entry as the LRU
    // candidate.
    ex.thread(vec![
        run(|s: &mut PrefixState| {
            s.resident = true;
            s.refs = 1;
        }),
        run(|s: &mut PrefixState| {
            if s.resident && s.refs == 1 {
                s.resident = false;
                s.refs = 0;
                s.freed = true;
            }
        }),
    ]);
    // Importer thread: one atomic probe (lookup + Arc clone under the
    // mutex), then a read of the imported bytes.
    ex.thread(vec![
        run(|s: &mut PrefixState| {
            if s.resident {
                s.refs += 1;
                s.imported = Some(true);
            } else {
                s.imported = Some(false);
            }
        }),
        run(|_s: &mut PrefixState| {
            // Reading imported bytes after eviction freed them is the
            // hazard; the invariant checks imported ∧ freed directly.
        }),
    ]);
    prefix_invariants(&mut ex);
    ex.final_check(|s| match (s.imported, s.resident, s.refs) {
        // Hit: the importer's hold pinned the entry past the sweep.
        (Some(true), true, 2) => Ok(()),
        // Miss: probed before publish or after eviction.
        (Some(false), true, 1) | (Some(false), false, 0) => Ok(()),
        other => Err(format!("inconsistent end state: {other:?}")),
    });
    let stats = ex.explore(PrefixState::default()).expect("protocol holds");
    // Two 2-step threads: C(4,2) = 6 interleavings.
    assert_eq!(stats.schedules, 6);
}

/// Seeded bug: the eviction sweep drops the `strong_count == 1` guard
/// (evicts purely by LRU order). The schedule where the importer's
/// probe lands between publish and the sweep must be caught — the pool
/// frees blocks a live sequence is decoding from.
#[test]
fn eviction_ignoring_refcounts_is_caught() {
    let mut ex: Explorer<PrefixState> = Explorer::new();
    ex.thread(vec![
        run(|s: &mut PrefixState| {
            s.resident = true;
            s.refs = 1;
        }),
        run(|s: &mut PrefixState| {
            if s.resident {
                s.evicted_held = s.refs > 1; // BUG: no refcount guard
                s.resident = false;
                s.refs -= 1;
                s.freed = s.refs == 0;
            }
        }),
    ]);
    ex.thread(vec![run(|s: &mut PrefixState| {
        if s.resident {
            s.refs += 1;
            s.imported = Some(true);
        } else {
            s.imported = Some(false);
        }
    })]);
    prefix_invariants(&mut ex);
    let v = ex.explore(PrefixState::default()).expect_err("must be caught");
    assert_eq!(
        v.schedule,
        vec![0, 1, 0],
        "minimal counterexample: publish, probe hit, then the unguarded sweep"
    );
    assert!(v.message.contains("live sequence"), "{v}");
}

/// Seeded bug: the probe's map lookup and its refcount bump happen as
/// two separate steps (check outside the pool mutex, clone later). The
/// eviction sweep slipping between them makes the importer clone from a
/// removed entry — the race the single-mutex probe makes impossible.
#[test]
fn split_probe_racing_eviction_is_caught() {
    let mut ex: Explorer<PrefixState> = Explorer::new();
    ex.thread(vec![
        run(|s: &mut PrefixState| {
            s.resident = true;
            s.refs = 1;
        }),
        run(|s: &mut PrefixState| {
            if s.resident && s.refs == 1 {
                s.resident = false;
                s.refs = 0;
                s.freed = true;
            }
        }),
    ]);
    ex.thread(vec![
        run(|s: &mut PrefixState| s.saw_hit = s.resident), // BUG: lookup only
        run(|s: &mut PrefixState| {
            if s.saw_hit {
                if s.resident {
                    s.refs += 1;
                    s.imported = Some(true);
                } else {
                    s.stale_import = true; // clone of a freed entry
                }
            } else {
                s.imported = Some(false);
            }
        }),
    ]);
    prefix_invariants(&mut ex);
    let v = ex.explore(PrefixState::default()).expect_err("must be caught");
    assert_eq!(
        v.schedule,
        vec![0, 1, 0, 1],
        "minimal counterexample: publish, stale lookup, sweep frees, clone"
    );
    assert!(v.message.contains("already removed"), "{v}");
}

// ---------------------------------------------------------------------
// Protocol 5: supervisor crash recovery vs. client cancellation.
// ---------------------------------------------------------------------

/// Lifecycle stage the dead engine's track was in when the supervisor
/// caught the panic (mirrors `serve::pool`'s `TrackStage` at the two
/// recovery-relevant points).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum CrashStage {
    /// Prefill in flight (or completed but not yet activated): nothing
    /// reached the client, the track retains its request spec.
    Prefilling,
    /// Tokens may already have streamed; the batch state died with the
    /// engine's Stack.
    Decoding,
}

#[derive(Clone)]
struct RecoverState {
    stage: CrashStage,
    /// The request still has a live track (supervisor or respawned
    /// engine owns it).
    tracked: bool,
    /// Client raised the shared cancel flag.
    cancel: bool,
    /// Terminal events emitted (must end at exactly 1).
    terminals: usize,
    /// Token-budget releases (must end at exactly 1).
    releases: usize,
    /// recover_shared re-queued the request for the respawned engine.
    requeued: bool,
}

fn recover_initial(stage: CrashStage) -> RecoverState {
    RecoverState {
        stage,
        tracked: true,
        cancel: false,
        terminals: 0,
        releases: 0,
        requeued: false,
    }
}

fn recover_invariants(ex: &mut Explorer<RecoverState>) {
    ex.invariant(|s| {
        if s.terminals > 1 {
            return Err("client answered twice".into());
        }
        if s.releases > 1 {
            return Err("budget reservation released twice".into());
        }
        if !s.tracked && s.terminals != s.releases {
            return Err(format!(
                "track gone with terminals {} != releases {}",
                s.terminals, s.releases
            ));
        }
        Ok(())
    });
    ex.final_check(|s| {
        if s.tracked {
            return Err("request stranded in recovery".into());
        }
        if s.terminals != 1 || s.releases != 1 {
            return Err(format!(
                "recovery ended with terminals {} releases {}",
                s.terminals, s.releases
            ));
        }
        Ok(())
    });
}

/// The respawned engine's first iteration: eviction sweep, then serve.
/// The cancel flag traveled with the track, so a cancel raised at ANY
/// point before this step is observed here (Cancelled), otherwise the
/// replayed request completes (Done) — either way one terminal, one
/// release.
fn respawned_engine_step(s: &mut RecoverState) {
    if s.requeued && s.tracked {
        s.terminals += 1;
        s.releases += 1;
        s.tracked = false;
    }
}

/// Supervisor recovery racing a client cancel yields exactly one
/// terminal and exactly one budget release on every schedule, for a
/// track caught in either stage.
#[test]
fn crash_recovery_racing_cancel_holds_under_all_schedules() {
    for stage in [CrashStage::Prefilling, CrashStage::Decoding] {
        let mut ex: Explorer<RecoverState> = Explorer::new();
        // Client thread: raise the shared cancel flag (at any point).
        ex.thread(vec![run(|s: &mut RecoverState| s.cancel = true)]);
        // Supervisor thread: recover_shared, then the respawned engine.
        ex.thread(vec![
            run(|s: &mut RecoverState| match s.stage {
                // Decode-stage: answer ReplicaLost now and release — the
                // cancel flag is moot, the track is gone either way.
                CrashStage::Decoding => {
                    s.terminals += 1;
                    s.releases += 1;
                    s.tracked = false;
                }
                // Prefill-stage: replay locally, reservation kept.
                CrashStage::Prefilling => s.requeued = true,
            }),
            run(respawned_engine_step),
        ]);
        recover_invariants(&mut ex);
        let stats = ex.explore(recover_initial(stage)).expect("recovery holds");
        // 1-step client against the 2-step supervisor: 3 interleavings.
        assert_eq!(stats.schedules, 3, "{stage:?}");
    }
}

/// Seeded bug: recovery answers a prefill-stage track with
/// `ReplicaLost` *and* re-queues it — the respawned engine answers a
/// second time. Caught as a double terminal on every schedule.
#[test]
fn recovery_answering_a_replayed_request_is_caught() {
    let mut ex: Explorer<RecoverState> = Explorer::new();
    ex.thread(vec![run(|s: &mut RecoverState| s.cancel = true)]);
    ex.thread(vec![
        run(|s: &mut RecoverState| {
            s.terminals += 1; // BUG: answered...
            s.releases += 1;
            s.requeued = true; // ...and replayed
        }),
        run(respawned_engine_step),
    ]);
    recover_invariants(&mut ex);
    let v = ex
        .explore(recover_initial(CrashStage::Prefilling))
        .expect_err("double answer must be caught");
    assert!(v.message.contains("answered twice"), "{v}");
}

/// Seeded bug: recovery releases the budget reservation of a track it
/// replays. The respawned engine releases again at the terminal —
/// caught as a double release (which would corrupt the pool's
/// token-budget accounting).
#[test]
fn recovery_releasing_a_replayed_reservation_is_caught() {
    let mut ex: Explorer<RecoverState> = Explorer::new();
    ex.thread(vec![run(|s: &mut RecoverState| s.cancel = true)]);
    ex.thread(vec![
        run(|s: &mut RecoverState| {
            s.releases += 1; // BUG: replayed tracks keep their reservation
            s.requeued = true;
        }),
        run(respawned_engine_step),
    ]);
    recover_invariants(&mut ex);
    let v = ex
        .explore(recover_initial(CrashStage::Prefilling))
        .expect_err("double release must be caught");
    assert!(v.message.contains("released twice"), "{v}");
}

/// Seeded drop-discipline bug: if the source replica never drops its
/// handoff sender after routing elsewhere (here: after terminating the
/// request locally), a decode replica parked in `recv` can never wake —
/// the explorer must report the deadlock schedule.
#[test]
fn missing_sender_drop_is_reported_as_deadlock() {
    let mut ex: Explorer<HandoffState> = Explorer::new();
    // Source terminates the request locally and — the seeded bug —
    // keeps its sender forever.
    ex.thread(vec![run(|s: &mut HandoffState| {
        s.terminals += 1;
        s.releases += 1;
        s.loc = Loc::Gone;
    })]);
    // Destination parked in a blocking handoff recv.
    ex.thread(vec![step(|s: &mut HandoffState| {
        if s.loc == Loc::Channel {
            s.loc = Loc::Dest;
            Step::Ran
        } else if !s.sender_alive {
            Step::Ran
        } else {
            Step::Blocked
        }
    })]);
    let v = ex.explore(handoff_initial()).expect_err("must deadlock");
    assert!(v.message.contains("deadlock"), "{v}");
}

// ---------------------------------------------------------------------
// Protocol 6: session-tier demotion vs. resume vs. cancel
// (kvcache::tier).
// ---------------------------------------------------------------------

/// Where a suspended session's block set lives in the tier.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum TierBlocks {
    /// Resident in DRAM, held by the tier.
    Hot,
    /// Demotion committed: the DRAM hold was swapped for a spill record.
    Cold,
}

/// Abstraction of `SessionTier`'s demotion discipline (plan under the
/// registry lock, write the spill record with no guard in scope, commit
/// under a fresh lock) racing a resume that consumes the session entry,
/// while the client concurrently cancels. `seq_refs` models the block
/// `Arc` clones a resuming sequence takes out of the registry; the real
/// tier's commit re-checks under the fresh lock that the victim session
/// is still present and otherwise only frees the now-orphaned record —
/// never the blocks themselves, which the resumer may hold.
#[derive(Clone)]
struct TierRaceState {
    /// Session entry present in the registry (consumed by the probe).
    session: bool,
    blocks: TierBlocks,
    /// Demotion planned and its record written to the spill file.
    spill_written: bool,
    /// The spill record occupies a live slot in the file.
    record_live: bool,
    /// Block-set holds owned by the resuming sequence.
    seq_refs: usize,
    /// Client raised the shared cancel flag (the resume's terminal is
    /// then `Cancelled` instead of `Done` — same accounting).
    cancel: bool,
    /// The resume probe ran (hit or honest miss).
    probed: bool,
    /// Terminal events emitted to the client (must end at exactly 1).
    terminals: usize,
    /// Budget releases (must end at exactly 1).
    releases: usize,
    /// A block set was deallocated while the resumer still held it.
    freed_held: bool,
    /// The probe paged in from a record that was already freed.
    stale_page_in: bool,
}

fn tier_initial() -> TierRaceState {
    TierRaceState {
        session: true,
        blocks: TierBlocks::Hot,
        spill_written: false,
        record_live: false,
        seq_refs: 0,
        cancel: false,
        probed: false,
        terminals: 0,
        releases: 0,
        freed_held: false,
        stale_page_in: false,
    }
}

/// Demotion plan + spill write: the victim is chosen under the registry
/// lock (so a session already consumed is never planned), the record
/// write happens with no guard in scope.
fn tier_plan_and_spill(s: &mut TierRaceState) {
    if s.session && s.blocks == TierBlocks::Hot && !s.spill_written {
        s.spill_written = true;
        s.record_live = true;
    }
}

/// Resume probe under the registry lock: consumes the session entry and
/// takes the blocks — hot Arcs are cloned, cold records paged in (which
/// frees the record's slot).
fn tier_probe(s: &mut TierRaceState) {
    if s.session {
        s.session = false;
        match s.blocks {
            TierBlocks::Hot => s.seq_refs = 1,
            TierBlocks::Cold => {
                if s.record_live {
                    s.record_live = false;
                    s.seq_refs = 1;
                } else {
                    s.stale_page_in = true;
                }
            }
        }
    }
    s.probed = true;
}

/// The resuming request's terminal: `Cancelled` or `Done` depending on
/// the flag, but exactly one event and one release either way; the
/// sequence's block holds drop with it.
fn tier_finish(s: &mut TierRaceState) {
    if s.probed {
        s.terminals += 1;
        s.releases += 1;
        s.seq_refs = 0;
    }
}

fn tier_invariants(ex: &mut Explorer<TierRaceState>) {
    ex.invariant(|s| {
        if s.freed_held {
            return Err("freed a block set the resuming sequence still holds".into());
        }
        if s.stale_page_in {
            return Err("paged in from a spill record that was already freed".into());
        }
        if s.terminals > 1 {
            return Err("client answered twice".into());
        }
        if s.releases > 1 {
            return Err("budget reservation released twice".into());
        }
        Ok(())
    });
    ex.final_check(|s| {
        if !s.probed || s.terminals != 1 || s.releases != 1 {
            return Err(format!(
                "resume ended with terminals {} releases {}",
                s.terminals, s.releases
            ));
        }
        if s.record_live {
            return Err("orphaned spill record leaked".into());
        }
        Ok(())
    });
}

/// The real protocol: the commit re-checks under a fresh lock whether
/// the victim session is still registered — swapping its hot blocks for
/// the record if so, and otherwise freeing only the orphaned record
/// (the resumer that consumed the session owns the blocks now). On
/// every interleaving with a cancelling client, the resume gets exactly
/// one terminal, no held block set is freed, and no record leaks.
#[test]
fn tier_demotion_racing_resume_and_cancel_holds_under_all_schedules() {
    let mut ex: Explorer<TierRaceState> = Explorer::new();
    // Client thread: raise the shared cancel flag (at any point).
    ex.thread(vec![run(|s: &mut TierRaceState| s.cancel = true)]);
    // Demotion thread (DRAM-budget sweep): plan + write, then commit.
    ex.thread(vec![
        run(tier_plan_and_spill),
        run(|s: &mut TierRaceState| {
            if s.spill_written && s.record_live {
                if s.session {
                    s.blocks = TierBlocks::Cold; // swap hold for record
                } else {
                    s.record_live = false; // orphan: resume won the race
                }
            }
        }),
    ]);
    // Resume thread: probe (consume + take blocks), then terminal.
    ex.thread(vec![run(tier_probe), run(tier_finish)]);
    tier_invariants(&mut ex);
    let stats = ex.explore(tier_initial()).expect("demotion protocol holds");
    // 1-, 2- and 2-step threads: 5!/(1!·2!·2!) = 30 interleavings.
    assert_eq!(stats.schedules, 30);
}

/// Seeded bug: the commit skips the staleness re-check and demotes
/// unconditionally — deallocating the DRAM block set even on the
/// schedule where the resume consumed the session (and cloned its hot
/// Arcs) between the plan and the commit. Caught as a free of held
/// blocks.
#[test]
fn tier_commit_without_staleness_check_frees_held_blocks() {
    let mut ex: Explorer<TierRaceState> = Explorer::new();
    ex.thread(vec![run(|s: &mut TierRaceState| s.cancel = true)]);
    ex.thread(vec![
        run(tier_plan_and_spill),
        run(|s: &mut TierRaceState| {
            if s.spill_written && s.record_live {
                // BUG: no staleness check — drop the DRAM copy outright.
                if s.seq_refs > 0 {
                    s.freed_held = true;
                }
                s.blocks = TierBlocks::Cold;
            }
        }),
    ]);
    ex.thread(vec![run(tier_probe), run(tier_finish)]);
    tier_invariants(&mut ex);
    let v = ex.explore(tier_initial()).expect_err("unguarded commit must be caught");
    assert!(v.message.contains("still holds"), "{v}");
}

/// Seeded bug: the commit notices the session is gone but forgets to
/// free the now-orphaned spill record — a slow leak of spill-file slots
/// under demotion/resume races. Caught by the final leak check.
#[test]
fn tier_commit_leaking_the_orphaned_record_is_caught() {
    let mut ex: Explorer<TierRaceState> = Explorer::new();
    ex.thread(vec![run(|s: &mut TierRaceState| s.cancel = true)]);
    ex.thread(vec![
        run(tier_plan_and_spill),
        run(|s: &mut TierRaceState| {
            if s.spill_written && s.record_live && s.session {
                s.blocks = TierBlocks::Cold;
            }
            // BUG: the !session arm (free the orphan) is missing.
        }),
    ]);
    ex.thread(vec![run(tier_probe), run(tier_finish)]);
    tier_invariants(&mut ex);
    let v = ex.explore(tier_initial()).expect_err("record leak must be caught");
    assert!(v.message.contains("leaked"), "{v}");
}

// ---------------------------------------------------------------------
// Protocol 7: per-head-group stage/commit isolation (real type).
// ---------------------------------------------------------------------

#[derive(Clone)]
struct GroupedRecallState {
    rs: ResidentSet,
    /// Fetch count reported by group 1's commit (None until it ran).
    fetched_g1: Option<usize>,
}

/// Group 0's recall thread restages twice while group 1 stages and
/// commits its own refresh. On every interleaving: group 0's visible
/// set never moves (its commit is not in this schedule), and group 1's
/// visible set is always one whole plan of group 1's own rankings —
/// restaging one group never blends another group's committed set.
#[test]
fn restaging_one_group_never_blends_anothers_committed_set() {
    let initial = {
        let mut rs = ResidentSet::new_grouped(16, 2, 2);
        rs.refresh_group(0, &[0, 1]);
        rs.refresh_group(1, &[8, 9]);
        GroupedRecallState { rs, fetched_g1: None }
    };

    let mut ex: Explorer<GroupedRecallState> = Explorer::new();
    // Group 0's recall ticks: two re-rankings racing the other group.
    ex.thread(vec![
        run(|s: &mut GroupedRecallState| {
            s.rs.stage_group(0, &[2, 3]);
        }),
        run(|s: &mut GroupedRecallState| {
            s.rs.stage_group(0, &[0, 4]);
        }),
    ]);
    // Group 1's recall tick + commit boundary.
    ex.thread(vec![
        run(|s: &mut GroupedRecallState| {
            s.rs.stage_group(1, &[8, 10]);
        }),
        run(|s: &mut GroupedRecallState| {
            s.fetched_g1 = Some(s.rs.commit_staged_group(1));
        }),
    ]);
    ex.invariant(|s| {
        let v0: Vec<usize> = s.rs.iter_group(0).collect();
        let v1: Vec<usize> = s.rs.iter_group(1).collect();
        // No commit for group 0 happens anywhere in this schedule, so
        // its visible set must hold its initial plan throughout — even
        // while group 1 commits.
        if v0 != vec![0, 1] {
            return Err(format!("group 0 visible set perturbed: {v0:?}"));
        }
        match v1.as_slice() {
            [8, 9] | [8, 10] => Ok(()),
            blend => Err(format!("group 1 shows a blended/foreign plan: {blend:?}")),
        }
    });
    ex.final_check(|s| {
        // Group 1's commit either saw its staged plan (stage ≤ commit)
        // or was a no-op; fetch accounting must agree with the view.
        let v1: Vec<usize> = s.rs.iter_group(1).collect();
        match (s.fetched_g1.unwrap(), v1.as_slice()) {
            (1, [8, 10]) | (0, [8, 9]) => {}
            other => return Err(format!("torn group-1 commit: {other:?}")),
        }
        // Group 0 staged twice and never committed: its latest ranking
        // must still be pending — group 1's commit must not consume it.
        if !s.rs.has_staged_group(0) {
            return Err("group 0's pending stage was consumed by group 1's commit".into());
        }
        if s.rs.staged_fetch_group(0) != [4] {
            return Err(format!(
                "group 0's pending fetch is not the latest ranking: {:?}",
                s.rs.staged_fetch_group(0)
            ));
        }
        Ok(())
    });
    let stats = ex.explore(initial).expect("all schedules hold");
    // Two 2-step threads: C(4, 2) = 6 interleavings.
    assert_eq!(stats.schedules, 6);
}
