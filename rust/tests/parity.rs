//! Cross-engine parity: every batched backend entry must agree with the
//! native rust engine on identical inputs. This is the contract that lets
//! the coordinator split attention between the "GPU" (the runtime
//! backend — interpreter by default, PJRT with `--features pjrt`) and the
//! "CPU" (native) and LSE-merge the partials (§3.2).

mod common;

use scoutattention::engines::Partial;
use scoutattention::kvcache::SeqKvCache;
use scoutattention::tensor::Tensor;
use scoutattention::util::Rng64;

fn rand_tensor(rng: &mut Rng64, shape: &[usize], scale: f32) -> Tensor {
    let n: usize = shape.iter().product();
    Tensor::from_vec(shape, (0..n).map(|_| (rng.f32() - 0.5) * scale).collect())
}

#[test]
fn pre_attn_matches_native() {
    let stack = common::stack();
    let spec = stack.gpu.spec.clone();
    let mut rng = Rng64::new(11);
    let x = rand_tensor(&mut rng, &[spec.batch, spec.d_model], 2.0);
    let pos: Vec<i32> = (0..spec.batch).map(|s| 3 + 2 * s as i32).collect();
    for layer in [0, spec.n_layers - 1] {
        let (q, k, v) = stack.gpu.pre_attn(&x, layer, &pos).unwrap();
        for s in 0..spec.batch {
            let (qn, kn, vn) = stack.native.pre_attn(x.rows(s, 1), layer, pos[s] as i64);
            common::assert_close(q.rows(s, 1), &qn, 2e-4, 2e-5, "q");
            common::assert_close(k.rows(s, 1), &kn, 2e-4, 2e-5, "k");
            common::assert_close(v.rows(s, 1), &vn, 2e-4, 2e-5, "v");
        }
    }
}

#[test]
fn qpred_matches_native_and_degenerate_equals_real_q() {
    let stack = common::stack();
    let spec = stack.gpu.spec.clone();
    let mut rng = Rng64::new(12);
    let x = rand_tensor(&mut rng, &[spec.batch, spec.d_model], 2.0);
    let pos: Vec<i32> = vec![9; spec.batch];
    let qp = stack.gpu.qpred(&x, 1, &pos).unwrap();
    for s in 0..spec.batch {
        let qn = stack.native.qpred(x.rows(s, 1), 1, 9);
        common::assert_close(qp.rows(s, 1), &qn, 2e-4, 2e-5, "qpred");
    }
    // degenerate: qpred with layer i's own input == the real q_i
    let (q, _, _) = stack.gpu.pre_attn(&x, 1, &pos).unwrap();
    common::assert_close(q.data(), qp.data(), 2e-4, 2e-5, "qpred==q same-layer");
}

fn filled_cache(stack: &scoutattention::harness::Stack, tokens: usize, seed: u64) -> SeqKvCache {
    let spec = stack.gpu.spec.clone();
    let mut cache = SeqKvCache::new(&spec);
    let mut rng = Rng64::new(seed);
    let w = spec.n_kv_heads * spec.head_dim;
    for _t in 0..tokens {
        for l in 0..spec.n_layers {
            let k: Vec<f32> = (0..w).map(|_| rng.f32() - 0.5).collect();
            let v: Vec<f32> = (0..w).map(|_| rng.f32() - 0.5).collect();
            cache.append_layer(l, &k, &v);
        }
        cache.advance();
    }
    cache
}

#[test]
fn sparse_attn_artifact_matches_native_blocks() {
    let stack = common::stack();
    let spec = stack.gpu.spec.clone();
    let (b, kb, bs, hkv, d) = (spec.batch, spec.k_blocks, spec.block_size, spec.n_kv_heads, spec.head_dim);
    let cache = filled_cache(&stack, spec.block_size * 6, 21);
    let mut rng = Rng64::new(22);
    let q = rand_tensor(&mut rng, &[b, spec.n_q_heads, d], 1.0);

    // gather blocks [2,0,4] for every sequence
    let blocks = vec![2usize, 0, 4];
    let w = hkv * d;
    let blk_w = bs * w;
    let mut k = Tensor::zeros(&[b, kb, bs, hkv, d]);
    let mut v = Tensor::zeros(&[b, kb, bs, hkv, d]);
    let mut m = Tensor::zeros(&[b, kb, bs]);
    for s in 0..b {
        cache.gather_blocks(
            1,
            &blocks,
            kb,
            &mut k.data_mut()[s * kb * blk_w..(s + 1) * kb * blk_w],
            &mut v.data_mut()[s * kb * blk_w..(s + 1) * kb * blk_w],
            &mut m.data_mut()[s * kb * bs..(s + 1) * kb * bs],
        );
    }
    let p = stack.gpu.sparse_attn(&q, &k, &v, &m).unwrap();
    for s in 0..b {
        let qrow = &q.rows(s, 1)[..spec.n_q_heads * d];
        let pn = stack.native.attend_blocks(qrow, &cache.layer_slabs(1), &blocks);
        common::assert_close(p.acc.rows(s, 1), &pn.acc, 5e-4, 1e-5, "acc");
        common::assert_close(p.l.rows(s, 1), &pn.l, 5e-4, 1e-6, "l");
        common::assert_close(p.m.rows(s, 1), &pn.m, 5e-4, 1e-5, "m");
    }
}

#[test]
fn block_scores_artifact_matches_native_scoring() {
    let stack = common::stack();
    let spec = stack.gpu.spec.clone();
    let cache = filled_cache(&stack, spec.block_size * 5 + 3, 31);
    let mut rng = Rng64::new(32);
    let (b, nb, hkv, d, hq) =
        (spec.batch, spec.n_blocks(), spec.n_kv_heads, spec.head_dim, spec.n_q_heads);
    let q = rand_tensor(&mut rng, &[b, hq, d], 1.0);
    // assemble digest operands from the cache's digest store (layer 0)
    let (kmin_t, kmax_t) = cache.digests.layer(0);
    let mut kmin = Tensor::zeros(&[b, nb, hkv, d]);
    let mut kmax = Tensor::zeros(&[b, nb, hkv, d]);
    for s in 0..b {
        // incomplete blocks hold +-inf sentinels; zero them for the
        // artifact (the coordinator only reads complete-block scores)
        let full = cache.full_blocks();
        let wrow = nb * hkv * d;
        for blk in 0..full {
            let off = s * wrow + blk * hkv * d;
            kmin.data_mut()[off..off + hkv * d]
                .copy_from_slice(&kmin_t.data()[blk * hkv * d..(blk + 1) * hkv * d]);
            kmax.data_mut()[off..off + hkv * d]
                .copy_from_slice(&kmax_t.data()[blk * hkv * d..(blk + 1) * hkv * d]);
        }
    }
    let scores = stack.gpu.block_scores(&q, &kmin, &kmax).unwrap();
    for s in 0..b {
        let native = scoutattention::sparse::score_blocks_native(
            &q.rows(s, 1)[..hq * d],
            &cache.digests,
            0,
            cache.full_blocks(),
            hq,
            hkv,
            d,
        );
        for blk in 0..cache.full_blocks() {
            let a = scores.at(&[s, blk]);
            let n = native[blk];
            assert!((a - n).abs() <= 1e-3 + 1e-3 * n.abs(), "blk {blk}: {a} vs {n}");
        }
    }
}

#[test]
fn merge_artifact_matches_native_merge() {
    let stack = common::stack();
    let spec = stack.gpu.spec.clone();
    let (b, hq, d) = (spec.batch, spec.n_q_heads, spec.head_dim);
    let mut rng = Rng64::new(41);
    let mk = |rng: &mut Rng64| {
        let acc = rand_tensor(rng, &[b, hq, d], 1.0);
        let m = rand_tensor(rng, &[b, hq], 2.0);
        let mut l = rand_tensor(rng, &[b, hq], 1.0);
        for x in l.data_mut() {
            *x = x.abs() + 0.1;
        }
        scoutattention::engines::gpu::BatchPartial { acc, m, l }
    };
    let a = mk(&mut rng);
    let bb = mk(&mut rng);
    let merged = stack.gpu.merge(&a, &bb).unwrap();
    for s in 0..b {
        let mut pa = Partial::empty(hq, d);
        pa.acc.copy_from_slice(a.acc.rows(s, 1));
        pa.m.copy_from_slice(a.m.rows(s, 1));
        pa.l.copy_from_slice(a.l.rows(s, 1));
        let mut pb = Partial::empty(hq, d);
        pb.acc.copy_from_slice(bb.acc.rows(s, 1));
        pb.m.copy_from_slice(bb.m.rows(s, 1));
        pb.l.copy_from_slice(bb.l.rows(s, 1));
        pa.merge(&pb);
        common::assert_close(merged.acc.rows(s, 1), &pa.acc, 2e-4, 1e-6, "macc");
        common::assert_close(merged.l.rows(s, 1), &pa.l, 2e-4, 1e-6, "ml");
    }
}

#[test]
fn decode_full_artifact_matches_native_oracle() {
    let stack = common::stack();
    let spec = stack.gpu.spec.clone();
    let (b, s_max) = (spec.batch, spec.max_seq);
    let w = spec.n_kv_heads * spec.head_dim;
    let n_tok = spec.block_size * 3 + 5;
    let cache = filled_cache(&stack, n_tok, 51);
    let mut rng = Rng64::new(52);
    let x = rand_tensor(&mut rng, &[b, spec.d_model], 1.0);
    // dense cache operands
    let mut kc = Tensor::zeros(&[spec.n_layers, b, s_max, spec.n_kv_heads, spec.head_dim]);
    let mut vc = Tensor::zeros(&[spec.n_layers, b, s_max, spec.n_kv_heads, spec.head_dim]);
    let seq_w = s_max * w;
    for layer in 0..spec.n_layers {
        for s in 0..b {
            let off = (layer * b + s) * seq_w;
            kc.data_mut()[off..off + n_tok * w].copy_from_slice(cache.k_rows(layer, 0, n_tok));
            vc.data_mut()[off..off + n_tok * w].copy_from_slice(cache.v_rows(layer, 0, n_tok));
        }
    }
    let pos = vec![n_tok as i32; b];
    let (logits, kn, vn) = stack.gpu.decode_full(&x, &kc, &vc, &pos).unwrap();
    for s in 0..b.min(2) {
        let (ln, knn, vnn) = stack.native.decode_step_full(x.rows(s, 1), &cache, n_tok as i64);
        // logits agree to float tolerance across two very different
        // execution orders (XLA fused scan vs per-token online softmax)
        common::assert_close(logits.rows(s, 1), &ln, 3e-3, 3e-3, "logits");
        for layer in 0..spec.n_layers {
            common::assert_close(
                &kn.rows(layer, 1)[s * w..(s + 1) * w],
                &knn[layer],
                1e-3,
                1e-4,
                "k_new",
            );
            common::assert_close(
                &vn.rows(layer, 1)[s * w..(s + 1) * w],
                &vnn[layer],
                1e-3,
                1e-4,
                "v_new",
            );
        }
    }
}

#[test]
fn prefill_artifact_consistent_with_native_prefill() {
    let stack = common::stack();
    let spec = stack.gpu.spec.clone();
    let n = spec.block_size * 2 + 7;
    let toks: Vec<u32> = (0..n).map(|i| 1 + (i as u32 * 7) % (spec.vocab as u32 - 1)).collect();
    // XLA prefill
    let mut x_seq = Tensor::zeros(&[spec.max_seq, spec.d_model]);
    for (t, &tok) in toks.iter().enumerate() {
        x_seq.rows_mut(t, 1).copy_from_slice(stack.gpu.weights.embed_token(tok));
    }
    let (k, v, h_last, logits_last) = stack.gpu.prefill(&x_seq, n).unwrap();
    // native prefill
    let mut cache = SeqKvCache::new(&spec);
    let h_native = stack.native.prefill(&toks, &mut cache);
    let w = spec.n_kv_heads * spec.head_dim;
    for layer in 0..spec.n_layers {
        common::assert_close(
            &k.rows(layer, 1)[..n * w],
            cache.k_rows(layer, 0, n),
            3e-3,
            3e-4,
            "prefill k",
        );
        common::assert_close(
            &v.rows(layer, 1)[..n * w],
            cache.v_rows(layer, 0, n),
            3e-3,
            3e-4,
            "prefill v",
        );
    }
    common::assert_close(h_last.data(), &h_native, 3e-3, 3e-4, "h_last");
    let logits_native = stack.native.lm_head(&h_native);
    common::assert_close(logits_last.data(), &logits_native, 5e-3, 5e-3, "prefill logits");
}

/// Satellite check for the interpreter backend itself: on a seeded tiny
/// spec (geometry deliberately different from test-tiny — GQA group 4,
/// odd tail), the interpreter's `sparse_attn` / `tail_attn` / `merge`
/// partials must match `engines/native.rs` within assert_close
/// tolerances. Built directly on `Runtime::for_spec`, so it also covers
/// manifest synthesis for non-builtin shapes.
#[test]
fn interpreter_partials_match_native_on_seeded_tiny_spec() {
    use scoutattention::engines::gpu::BatchPartial;
    use scoutattention::engines::{GpuEngine, NativeEngine};
    use scoutattention::model::{ModelSpec, Weights};
    use scoutattention::runtime::Runtime;
    use std::sync::Arc;

    let spec = ModelSpec {
        name: "interp-parity".into(),
        n_layers: 2,
        d_model: 48,
        n_q_heads: 8,
        n_kv_heads: 2,
        head_dim: 12,
        d_ff: 96,
        vocab: 64,
        max_seq: 96,
        block_size: 8,
        k_blocks: 3,
        batch: 3,
        rope_theta: 10000.0,
    };
    spec.validate().unwrap();
    let rt = Arc::new(Runtime::for_spec(&spec).unwrap());
    assert_eq!(rt.backend_name(), "interpreter");
    let weights = Weights::generate(&spec, 77, 1.0);
    let gpu = GpuEngine::new(rt, weights.clone()).unwrap();
    let native = NativeEngine::new(spec.clone(), weights);

    // 6 full blocks + a 5-token tail
    let (b, kb, bs, hkv, hq, d) =
        (spec.batch, spec.k_blocks, spec.block_size, spec.n_kv_heads, spec.n_q_heads, spec.head_dim);
    let w = hkv * d;
    let mut cache = SeqKvCache::new(&spec);
    let mut rng = Rng64::new(81);
    for _t in 0..bs * 6 + 5 {
        for l in 0..spec.n_layers {
            let kr: Vec<f32> = (0..w).map(|_| rng.f32() - 0.5).collect();
            let vr: Vec<f32> = (0..w).map(|_| rng.f32() - 0.5).collect();
            cache.append_layer(l, &kr, &vr);
        }
        cache.advance();
    }
    let q = rand_tensor(&mut rng, &[b, hq, d], 1.0);

    // sparse_attn over gathered blocks [4, 1, 0]
    let blocks = vec![4usize, 1, 0];
    let blk_w = bs * w;
    let mut k = Tensor::zeros(&[b, kb, bs, hkv, d]);
    let mut v = Tensor::zeros(&[b, kb, bs, hkv, d]);
    let mut m = Tensor::zeros(&[b, kb, bs]);
    for s in 0..b {
        cache.gather_blocks(
            1,
            &blocks,
            kb,
            &mut k.data_mut()[s * kb * blk_w..(s + 1) * kb * blk_w],
            &mut v.data_mut()[s * kb * blk_w..(s + 1) * kb * blk_w],
            &mut m.data_mut()[s * kb * bs..(s + 1) * kb * bs],
        );
    }
    let p_sparse = gpu.sparse_attn(&q, &k, &v, &m).unwrap();
    for s in 0..b {
        let qrow = &q.rows(s, 1)[..hq * d];
        let pn = native.attend_blocks(qrow, &cache.layer_slabs(1), &blocks);
        common::assert_close(p_sparse.acc.rows(s, 1), &pn.acc, 1e-5, 1e-6, "interp sparse acc");
        common::assert_close(p_sparse.m.rows(s, 1), &pn.m, 1e-5, 1e-6, "interp sparse m");
        common::assert_close(p_sparse.l.rows(s, 1), &pn.l, 1e-5, 1e-6, "interp sparse l");
    }

    // tail_attn over the 5-token tail + per-sequence current token
    let k_new = rand_tensor(&mut rng, &[b, hkv, d], 1.0);
    let v_new = rand_tensor(&mut rng, &[b, hkv, d], 1.0);
    let mut kt = Tensor::zeros(&[b, 1, bs, hkv, d]);
    let mut vt = Tensor::zeros(&[b, 1, bs, hkv, d]);
    let mut mt = Tensor::zeros(&[b, 1, bs]);
    let tail = cache.tail_len();
    assert_eq!(tail, 5);
    for s in 0..b {
        let ks = &mut kt.data_mut()[s * bs * w..(s + 1) * bs * w];
        let vs = &mut vt.data_mut()[s * bs * w..(s + 1) * bs * w];
        let ms = &mut mt.data_mut()[s * bs..(s + 1) * bs];
        cache.gather_tail(1, ks, vs, ms);
        ks[tail * w..(tail + 1) * w].copy_from_slice(&k_new.rows(s, 1)[..w]);
        vs[tail * w..(tail + 1) * w].copy_from_slice(&v_new.rows(s, 1)[..w]);
        ms[tail] = 1.0;
    }
    let p_tail = gpu.tail_attn(&q, &kt, &vt, &mt).unwrap();
    for s in 0..b {
        let qrow = &q.rows(s, 1)[..hq * d];
        let pn = native.attend_tail(
            qrow,
            &cache,
            1,
            &k_new.rows(s, 1)[..w],
            &v_new.rows(s, 1)[..w],
        );
        common::assert_close(p_tail.acc.rows(s, 1), &pn.acc, 1e-5, 1e-6, "interp tail acc");
        common::assert_close(p_tail.l.rows(s, 1), &pn.l, 1e-5, 1e-6, "interp tail l");
    }

    // merge of the two partials vs the native per-sequence LSE merge
    let merged = gpu.merge(&p_sparse, &p_tail).unwrap();
    let rowp = |bp: &BatchPartial, s: usize| {
        let mut p = Partial::empty(hq, d);
        p.acc.copy_from_slice(bp.acc.rows(s, 1));
        p.m.copy_from_slice(bp.m.rows(s, 1));
        p.l.copy_from_slice(bp.l.rows(s, 1));
        p
    };
    for s in 0..b {
        let mut pa = rowp(&p_sparse, s);
        let pb = rowp(&p_tail, s);
        pa.merge(&pb);
        common::assert_close(merged.acc.rows(s, 1), &pa.acc, 1e-5, 1e-6, "interp merge acc");
        common::assert_close(merged.l.rows(s, 1), &pa.l, 1e-5, 1e-6, "interp merge l");
        common::assert_close(merged.m.rows(s, 1), &pa.m, 1e-5, 1e-6, "interp merge m");
    }
}

#[test]
fn lm_head_matches_native() {
    let stack = common::stack();
    let spec = stack.gpu.spec.clone();
    let mut rng = Rng64::new(61);
    let x = rand_tensor(&mut rng, &[spec.batch, spec.d_model], 1.5);
    let logits = stack.gpu.lm_head(&x).unwrap();
    for s in 0..spec.batch {
        let ln = stack.native.lm_head(x.rows(s, 1));
        common::assert_close(logits.rows(s, 1), &ln, 1e-3, 1e-4, "lm_head");
    }
}

#[test]
fn digest_build_artifact_matches_store() {
    let stack = common::stack();
    let spec = stack.gpu.spec.clone();
    let (b, nb, bs, hkv, d) = (spec.batch, spec.n_blocks(), spec.block_size, spec.n_kv_heads, spec.head_dim);
    let mut rng = Rng64::new(71);
    let kblocks = rand_tensor(&mut rng, &[b, nb, bs, hkv, d], 1.0);
    let (kmin, kmax) = stack.gpu.digest_build(&kblocks).unwrap();
    // spot-check vs a DigestStore rebuild on sequence 0, block 3
    let mut store = scoutattention::kvcache::DigestStore::new(&spec);
    let blk_w = bs * hkv * d;
    let slab = &kblocks.data()[3 * blk_w..4 * blk_w];
    store.rebuild_block(0, 3, slab);
    let (lo, hi) = store.block(0, 3);
    common::assert_close(&kmin.rows(0, 1)[3 * hkv * d..4 * hkv * d], lo, 1e-6, 0.0, "kmin");
    common::assert_close(&kmax.rows(0, 1)[3 * hkv * d..4 * hkv * d], hi, 1e-6, 0.0, "kmax");
}
