//! Staged-recall semantics through the real Scout scheduler (§3.4 made
//! structural): a resident set re-ranked by a recall tick at step *t*
//! must not change the blocks visible to GPU attention until step
//! *t+1*'s same layer, and committing anywhere inside that window is
//! numerically equivalent (the set is simply not consulted in between —
//! which is exactly what gives the fetch a full-step PCIe window).

mod common;

use scoutattention::config::{Method, RecallPolicy};
use scoutattention::coordinator::{Batch, DecodeScheduler};
use scoutattention::harness::{self, Stack};
use scoutattention::workload::{LengthMix, WorkloadGen};

const INTERVAL: usize = 3;

fn recall_stack(base: &Stack) -> Stack {
    let mut cfg = base.cfg.clone();
    cfg.scout.recall = RecallPolicy::Fixed { interval: INTERVAL };
    Stack {
        cfg,
        rt: base.rt.clone(),
        gpu: base.gpu.clone(),
        native: base.native.clone(),
    }
}

fn one_request(stack: &Stack, new_tokens: usize) -> scoutattention::coordinator::RequestSpec {
    let spec = stack.gpu.spec.clone();
    let mut gen =
        WorkloadGen::new(13, spec.vocab, LengthMix::Fixed(spec.block_size * 10), new_tokens);
    gen.take(1).pop().unwrap()
}

/// A tick at step t stages; the stage is invisible through the end of
/// step t and is consumed (committed) during step t+1.
#[test]
fn staged_set_invisible_until_next_step_same_layer() {
    let base = common::stack();
    let stack = recall_stack(&base);
    let spec = stack.gpu.spec.clone();
    let mut sched = stack.scheduler(Method::Scout, None);
    let mut batch = Batch::new(spec.clone(), spec.k_blocks, 1);
    sched.admit(&mut batch, &one_request(&stack, 2 * INTERVAL + 2)).unwrap();

    // Run up to just before the first tick fires (countdowns start at
    // INTERVAL, so the fire lands in step INTERVAL).
    for _ in 0..INTERVAL - 1 {
        let st = sched.step(&mut batch).unwrap();
        assert_eq!(st.recall_staged_blocks(), 0, "no tick before the interval");
        assert!(batch.seqs[0].resident.iter().all(|r| !r.has_staged()));
    }

    // Snapshot the visible sets, then take the staging step.
    let before: Vec<Vec<usize>> =
        batch.seqs[0].resident.iter().map(|r| r.iter().collect()).collect();
    let st = sched.step(&mut batch).unwrap();
    let mut staged_layers = 0;
    for (layer, r) in batch.seqs[0].resident.iter().enumerate() {
        // Every layer ticked this step, so every layer holds a staged
        // set — and the *visible* set is byte-for-byte what it was
        // before the step (nothing committed mid-step).
        assert!(r.has_staged(), "layer {layer} must hold a staged set");
        let visible: Vec<usize> = r.iter().collect();
        assert_eq!(visible, before[layer], "layer {layer} changed visibly at stage time");
        staged_layers += 1;
    }
    assert_eq!(staged_layers, spec.n_layers);
    // The staged fetch is what the stats (and the timing plane) see.
    let staged_fetch: usize =
        batch.seqs[0].resident.iter().map(|r| r.staged_fetch().len()).sum();
    assert_eq!(st.recall_staged_blocks(), staged_fetch);
    assert_eq!(st.recall_blocks(), 0, "nothing commits in the staging step");
    let staged_target: Vec<Option<Vec<usize>>> =
        batch.seqs[0].resident.iter().map(|r| r.staged_blocks()).collect();

    // Step t+1: every staged set is committed at its own layer (and the
    // next tick is still INTERVAL-1 steps away, so nothing re-stages).
    let st = sched.step(&mut batch).unwrap();
    for (layer, r) in batch.seqs[0].resident.iter().enumerate() {
        assert!(!r.has_staged(), "layer {layer} staged set must be consumed");
        let visible: Vec<usize> = r.iter().collect();
        assert_eq!(
            staged_target[layer].as_deref(),
            Some(visible.as_slice()),
            "layer {layer} must now show the staged set"
        );
    }
    assert_eq!(
        st.recall_blocks(),
        staged_fetch,
        "commit must report exactly the staged fetch arriving"
    );
    assert_eq!(st.recall_staged_blocks(), 0, "no tick in the commit step");
}

/// Committing at the scheduler's boundary (step t+1, same layer) is
/// numerically identical to committing at the window's other end (right
/// after step t) — the set is not consulted in between. A commit that
/// happened any *earlier* (inside step t, before the partition) would
/// change selection inputs; the visibility test above pins that down.
#[test]
fn commit_boundary_is_numerically_equivalent_across_the_window() {
    let base = common::stack();
    let stack = recall_stack(&base);
    let spec = stack.gpu.spec.clone();
    let reqs = vec![one_request(&stack, 16)];

    // Run A: the scheduler commits at step t+1's same layer.
    let run_a = harness::run_method(&stack, Method::Scout, reqs.clone(), 1000, None).unwrap();
    assert!(
        run_a.stats.iter().any(|s| s.recall_staged_blocks() > 0),
        "recall must fire during the run"
    );

    // Run B: force-commit every staged set between steps (the earliest
    // legal point of the one-step window).
    let mut sched = stack.scheduler(Method::Scout, None);
    let mut batch = Batch::new(spec.clone(), spec.k_blocks, 1);
    for r in &reqs {
        sched.admit(&mut batch, r).unwrap();
    }
    let mut steps = 0;
    while batch.live() > 0 && steps < 1000 {
        sched.step(&mut batch).unwrap();
        for seq in batch.seqs.iter_mut() {
            for r in seq.resident.iter_mut() {
                r.commit_staged();
            }
        }
        batch.reap();
        steps += 1;
    }
    let mut outputs = std::mem::take(&mut batch.finished);
    outputs.sort_by_key(|o| o.id);

    assert_eq!(outputs.len(), run_a.outputs.len());
    for (a, b) in run_a.outputs.iter().zip(&outputs) {
        assert_eq!(a.id, b.id);
        assert_eq!(
            a.generated, b.generated,
            "token stream must be identical across the commit window"
        );
    }
}

/// Per-sequence worker groups must not perturb the schedule: the e2e
/// arms cover agreement with the oracle; here we pin that folding every
/// sequence onto one shared group (worker_groups=1) and the default
/// per-slot sharding produce identical token streams on a multi-chunk,
/// recall-enabled workload — concurrency layout is not allowed to leak
/// into numerics.
#[test]
fn group_layout_never_changes_tokens() {
    let base = common::stack();
    let stack = recall_stack(&base);
    let spec = stack.gpu.spec.clone();
    let reqs: Vec<_> = {
        let mut gen = WorkloadGen::new(29, spec.vocab, LengthMix::Fixed(spec.block_size * 8), 8);
        gen.take(spec.batch * 2 + 1)
    };
    let sharded = harness::run_method(&stack, Method::Scout, reqs.clone(), 2000, None).unwrap();

    let mut cfg = stack.cfg.clone();
    cfg.scout.worker_groups = 1;
    cfg.scout.threads_per_group = 2;
    let folded_stack = Stack {
        cfg,
        rt: stack.rt.clone(),
        gpu: stack.gpu.clone(),
        native: stack.native.clone(),
    };
    let folded = harness::run_method(&folded_stack, Method::Scout, reqs, 2000, None).unwrap();

    assert_eq!(sharded.outputs.len(), folded.outputs.len());
    for (a, b) in sharded.outputs.iter().zip(&folded.outputs) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.generated, b.generated, "request {}", a.id);
    }
}
