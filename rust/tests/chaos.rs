//! Chaos suite: seeded fault injection against the full serving plane.
//!
//! Every test here arms the process-global fault registry
//! ([`scoutattention::util::faults`]), drives a real `EnginePool`
//! through the induced failure, and asserts the fault-tolerance
//! contract end to end:
//!
//! - every in-flight client receives **exactly one** terminal event,
//! - the pool's `inflight_tokens` reservation returns to zero,
//! - the pool serves at full replica count again after the supervisor
//!   respawns the crashed engine,
//! - requests replayed after a crash produce **byte-identical** output
//!   to an unfaulted reference run (prefill replay is deterministic),
//! - `replica_lost` is retryable and `deadline_exceeded` /
//!   `overloaded` load-shed terminals carry honest hints,
//! - session-tier storage faults stay contained: a full spill device
//!   sheds cached *sessions* (never failing a client request), and a
//!   page-in failure fails the one resuming request with a structured
//!   error while the pool keeps serving.
//!
//! The registry is global, so the suite serializes through a gate
//! mutex and disarms via RAII even on assertion panics. CI runs this
//! binary with `--test-threads=1`; `SCOUT_CHAOS_QUICK=1` shrinks the
//! request counts for smoke lanes.

mod common;

use std::sync::{Mutex, MutexGuard};
use std::time::{Duration, Instant};

use scoutattention::config::{ReplicaRole, RunConfig};
use scoutattention::serve::{EnginePool, StreamEvent, StreamHandle, Submission};
use scoutattention::util::{clock, faults, Json};

const WAIT: Duration = Duration::from_secs(120);

/// Serializes tests: the fault registry is process-global state.
static GATE: Mutex<()> = Mutex::new(());

fn gate() -> MutexGuard<'static, ()> {
    GATE.lock().unwrap_or_else(|e| e.into_inner())
}

/// RAII disarm: rules must never leak into the next test, even when an
/// assertion in this one panics.
struct Disarm;

impl Drop for Disarm {
    fn drop(&mut self) {
        faults::disarm();
    }
}

fn armed(spec: &str) -> Disarm {
    faults::arm(spec).expect("valid fault spec");
    Disarm
}

fn quick() -> bool {
    std::env::var("SCOUT_CHAOS_QUICK").is_ok()
}

/// Deterministic prompt in the test-tiny vocab (256), avoiding pad 0.
fn prompt(len: usize, salt: u32) -> Vec<u32> {
    (0..len as u32).map(|i| 1 + (i * 29 + salt * 11) % 255).collect()
}

fn base_cfg(replicas: usize) -> RunConfig {
    let mut cfg = RunConfig::for_preset(common::PRESET);
    cfg.server.replicas = replicas;
    cfg
}

fn wait_terminal(h: &StreamHandle) -> StreamEvent {
    loop {
        match h.recv_timeout(WAIT) {
            Some(StreamEvent::Token { .. }) => continue,
            Some(ev) => return ev,
            None => panic!("stream closed without a terminal event"),
        }
    }
}

/// The wire contract under fault injection: one terminal, then silence.
fn assert_single_terminal(h: &StreamHandle) {
    assert!(
        h.recv_timeout(Duration::from_millis(20)).is_none(),
        "request {}: second event after its terminal",
        h.id
    );
}

fn expect_done(ev: StreamEvent) -> Vec<u32> {
    match ev {
        StreamEvent::Done(out) => out.generated,
        other => panic!("expected Done, got {other:?}"),
    }
}

/// Poll `{"stats":true}` until `pred` holds (terminals are sent before
/// some counters settle, e.g. a respawn finishes after its recovery
/// terminals went out).
fn settle(pool: &EnginePool, what: &str, pred: impl Fn(&Json) -> bool) -> Json {
    let t0 = Instant::now();
    loop {
        let s = pool.stats();
        if pred(&s) {
            return s;
        }
        assert!(t0.elapsed() < WAIT, "stats never settled ({what}): {}", s.to_string());
        std::thread::sleep(Duration::from_millis(5));
    }
}

fn replica_states(stats: &Json) -> Vec<String> {
    stats
        .get("replicas")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|r| r.req_str("state").unwrap().to_string())
        .collect()
}

/// The acceptance scenario: a seeded engine panic on replica 0 while a
/// fleet of requests is in flight. Every client gets exactly one
/// terminal; completed requests match an unfaulted reference run
/// byte-for-byte (replayed prefills included); `replica_lost` victims
/// succeed on retry with the same bytes; reservations drain to zero;
/// and the pool is back at full replica count ("ready" everywhere,
/// restart counted) afterwards.
#[test]
fn replica_panic_failover_settles_every_client() {
    let _g = gate();
    let n_req = if quick() { 4 } else { 8 };
    let new_tokens = 6;
    let prompts: Vec<Vec<u32>> = (0..n_req).map(|i| prompt(24, i as u32)).collect();

    // Reference run: same pool shape, registry disarmed. Also pins the
    // zero-cost contract — a disarmed registry must not perturb
    // behavior (`faults_injected` stays flat).
    let injected_before = faults::injected_total();
    let reference: Vec<Vec<u32>> = {
        let pool = EnginePool::start(base_cfg(2)).expect("reference pool start");
        let outs = prompts
            .iter()
            .map(|p| expect_done(wait_terminal(&pool.submit(Submission::new(p.clone(), new_tokens)))))
            .collect();
        pool.shutdown().expect("reference shutdown");
        outs
    };
    assert_eq!(
        faults::injected_total(),
        injected_before,
        "disarmed registry must inject nothing"
    );

    // Chaos run: arm through the config plumbing (`scout.faults`), the
    // same path a chaos deployment would use. Replica 0 panics on its
    // 3rd engine-loop iteration — mid-prefill or mid-decode depending
    // on arrival interleaving; the contract must hold either way.
    let _d = Disarm;
    let mut cfg = base_cfg(2);
    cfg.scout.faults = "replica.panic[0]=panic@3".to_string();
    let pool = EnginePool::start(cfg).expect("chaos pool start");
    let handles: Vec<StreamHandle> = prompts
        .iter()
        .map(|p| pool.submit(Submission::new(p.clone(), new_tokens).streaming()))
        .collect();

    let mut lost = Vec::new();
    for (i, h) in handles.iter().enumerate() {
        match wait_terminal(h) {
            StreamEvent::Done(out) => {
                assert_eq!(
                    out.generated, reference[i],
                    "request {i}: output diverged from the unfaulted reference \
                     (prefill replay must be byte-identical)"
                );
            }
            StreamEvent::ReplicaLost { id, retry_after_ms } => {
                assert_eq!(id, h.id);
                assert!(retry_after_ms > 0, "replica_lost must carry a retry hint");
                lost.push(i);
            }
            other => panic!("request {i}: expected Done or ReplicaLost, got {other:?}"),
        }
        assert_single_terminal(h);
    }

    // Settlement: reservations at zero, the panic counted, replica 0
    // respawned and every replica back in rotation.
    let stats = settle(&pool, "post-panic recovery", |s| {
        s.req_usize("inflight_tokens").unwrap() == 0
            && s.req_usize("restarts").unwrap() >= 1
            && replica_states(s).iter().all(|st| st == "ready")
    });
    assert_eq!(stats.req_usize("failed_replicas").unwrap(), 0, "respawn must clear `down`");
    assert!(
        faults::injected_total() > injected_before,
        "the armed panic rule must have fired"
    );

    // Retryability: every replica_lost victim succeeds on resubmit,
    // with the reference bytes.
    for i in lost {
        let out = expect_done(wait_terminal(
            &pool.submit(Submission::new(prompts[i].clone(), new_tokens)),
        ));
        assert_eq!(out, reference[i], "request {i}: retry after replica_lost diverged");
    }

    // Full capacity: a fresh fleet completes on the respawned pool.
    let fresh: Vec<StreamHandle> = (0..n_req)
        .map(|i| pool.submit(Submission::new(prompt(24, 100 + i as u32), new_tokens)))
        .collect();
    for h in &fresh {
        expect_done(wait_terminal(h));
    }
    settle(&pool, "post-retry drain", |s| s.req_usize("inflight_tokens").unwrap() == 0);
    pool.shutdown().expect("chaos shutdown");
}

/// Deadlines answer a wedged replica: a stall fault holds the engine
/// loop 50ms per iteration, so a 40ms deadline expires between
/// iterations and the sweep emits `DeadlineExceeded` — and an already
/// expired submission is refused at admission without ever reserving
/// budget.
#[test]
fn deadline_exceeded_terminal_under_stall_and_at_admission() {
    let _g = gate();
    let _d = armed("replica.stall[0]=stall@nth:1");
    let pool = EnginePool::start(base_cfg(1)).expect("pool start");

    let h = pool.submit(Submission::new(prompt(24, 1), 50).with_timeout_ms(40));
    match wait_terminal(&h) {
        StreamEvent::DeadlineExceeded { id, elapsed_ms } => {
            assert_eq!(id, h.id);
            assert!(elapsed_ms >= 40, "elapsed {elapsed_ms}ms must cover the deadline");
        }
        other => panic!("expected DeadlineExceeded, got {other:?}"),
    }
    assert_single_terminal(&h);

    // Admission gate: a submission whose deadline already passed is
    // answered synchronously, before any reservation or placement.
    let expired = Submission {
        prompt: prompt(8, 2),
        max_new_tokens: 4,
        stream: false,
        session: None,
        session_id: None,
        arrival_us: clock::now_us().saturating_sub(10_000_000),
        timeout_ms: 1,
    };
    let h = pool.submit(expired);
    match wait_terminal(&h) {
        StreamEvent::DeadlineExceeded { elapsed_ms, .. } => {
            assert!(elapsed_ms >= 1000, "backdated by 10s, got {elapsed_ms}ms");
        }
        other => panic!("expected admission-time DeadlineExceeded, got {other:?}"),
    }

    let stats = settle(&pool, "deadline settlement", |s| {
        s.req_usize("inflight_tokens").unwrap() == 0
    });
    // Only the engine-sweep path counts per-replica (the admission gate
    // answers before any replica owns the request).
    assert!(stats.req_usize("deadline_exceeded").unwrap() >= 1, "the sweep must count");
    pool.shutdown().expect("shutdown");
}

/// A dead handoff destination (send fault) yields the retryable
/// `ReplicaLost` terminal, and the pool keeps serving: the once-shot
/// rule is spent, so the retry migrates cleanly.
#[test]
fn handoff_send_fault_is_retryable_replica_lost() {
    let _g = gate();
    let _d = armed("handoff.send=err@1");
    let mut cfg = base_cfg(2);
    cfg.server.roles = vec![ReplicaRole::Prefill, ReplicaRole::Decode];
    let pool = EnginePool::start(cfg).expect("pool start");

    let h = pool.submit(Submission::new(prompt(24, 1), 4));
    match wait_terminal(&h) {
        StreamEvent::ReplicaLost { id, retry_after_ms } => {
            assert_eq!(id, h.id);
            assert!(retry_after_ms > 0);
        }
        other => panic!("expected ReplicaLost, got {other:?}"),
    }
    assert_single_terminal(&h);

    let retry = pool.submit(Submission::new(prompt(24, 1), 4));
    expect_done(wait_terminal(&retry));
    settle(&pool, "handoff-fault settlement", |s| {
        s.req_usize("inflight_tokens").unwrap() == 0
    });
    pool.shutdown().expect("shutdown");
}

/// A refused KV import on the decode side terminates the request with
/// a `Failed` naming the rejection, releases its reservation, and the
/// next migration goes through.
#[test]
fn kv_import_fault_rejects_the_handoff() {
    let _g = gate();
    let _d = armed("kv.import=err@1");
    let mut cfg = base_cfg(2);
    cfg.server.roles = vec![ReplicaRole::Prefill, ReplicaRole::Decode];
    let pool = EnginePool::start(cfg).expect("pool start");

    let h = pool.submit(Submission::new(prompt(24, 1), 4));
    match wait_terminal(&h) {
        StreamEvent::Failed { id, error } => {
            assert_eq!(id, h.id);
            assert!(error.contains("handoff import rejected"), "{error}");
        }
        other => panic!("expected Failed, got {other:?}"),
    }
    assert_single_terminal(&h);

    let retry = pool.submit(Submission::new(prompt(24, 1), 4));
    expect_done(wait_terminal(&retry));
    settle(&pool, "import-fault settlement", |s| {
        s.req_usize("inflight_tokens").unwrap() == 0
    });
    pool.shutdown().expect("shutdown");
}

/// KV allocation failure at admission degrades gracefully: the client
/// gets a structured `overloaded` rejection naming the shed (with an
/// honest backoff hint), not a hard failure — and the pool serves the
/// retry.
#[test]
fn kv_alloc_fault_sheds_load_with_honest_backoff() {
    let _g = gate();
    let _d = armed("kv.alloc=err@1");
    let pool = EnginePool::start(base_cfg(1)).expect("pool start");

    let h = pool.submit(Submission::new(prompt(24, 1), 4));
    match wait_terminal(&h) {
        StreamEvent::Rejected(r) => {
            assert_eq!(r.id, h.id);
            assert_eq!(r.code, scoutattention::serve::RejectCode::Overloaded);
            assert!(r.reason.contains("load shed"), "{}", r.reason);
            assert!(r.retry_after_ms > 0, "shed must carry a retry hint");
        }
        other => panic!("expected overloaded rejection, got {other:?}"),
    }
    assert_single_terminal(&h);

    let retry = pool.submit(Submission::new(prompt(24, 1), 4));
    expect_done(wait_terminal(&retry));
    let stats = settle(&pool, "shed settlement", |s| {
        s.req_usize("inflight_tokens").unwrap() == 0
    });
    assert!(
        stats.get("rejected_by").unwrap().req_usize("overloaded").unwrap() >= 1,
        "the shed must count as an overloaded rejection"
    );
    pool.shutdown().expect("shutdown");
}

/// ENOSPC on the spill device sheds cached *sessions*, never client
/// requests: with the DRAM budget forcing demotions and every spill
/// write failing, the suspending request still completes `Done`, the
/// tier counts an honest `shed`, nothing reaches the file, and the
/// follow-up that lost its session misses and re-prefills cleanly.
#[test]
fn tier_enospc_sheds_sessions_not_requests() {
    let _g = gate();
    let _d = armed("tier.enospc=err@always");
    let mut cfg = base_cfg(1);
    cfg.scout.tier_dram_blocks = 3; // one session's working set
    let pool = EnginePool::start(cfg).expect("pool start");

    let pa = prompt(32, 1);
    let first = expect_done(wait_terminal(
        &pool.submit(Submission::new(pa.clone(), 6).with_session_id("a")),
    ));

    // Suspending a second session demands demoting "a"'s blocks; the
    // injected ENOSPC must shed "a" silently, not fail "b".
    let hb = pool.submit(Submission::new(prompt(32, 2), 6).with_session_id("b"));
    expect_done(wait_terminal(&hb));
    assert_single_terminal(&hb);
    let tier = pool.stats().get("tier").expect("tier stats").clone();
    assert!(tier.req_usize("shed").unwrap() >= 1, "failed spill must count as a shed");
    assert_eq!(tier.req_usize("spilled").unwrap(), 0, "no record may reach a full device");
    assert_eq!(tier.req_usize("spill_file_bytes").unwrap(), 0);

    // The shed session is simply gone: the same-key follow-up misses
    // and re-prefills its full history to a clean Done.
    let mut hist = pa;
    hist.extend_from_slice(&first);
    let follow = pool.submit(Submission::new(hist, 4).with_session_id("a"));
    expect_done(wait_terminal(&follow));
    let stats = settle(&pool, "enospc settlement", |s| {
        s.req_usize("inflight_tokens").unwrap() == 0
    });
    let tier = stats.get("tier").unwrap();
    assert_eq!(tier.req_usize("resumed").unwrap(), 0, "shed sessions cannot resume");
    assert!(tier.req_usize("misses").unwrap() >= 3, "every probe was an honest miss");
    pool.shutdown().expect("shutdown");
}

/// A page-in failure while resuming a spilled session fails exactly
/// that request with a structured error naming the tier — never a
/// panic, never a silent fresh prefill that would mask storage damage.
/// The reservation is released, the session is consumed, and the
/// retry (rule spent) prefills fresh to a clean Done.
#[test]
fn tier_page_in_fault_fails_the_resume_structurally() {
    let _g = gate();
    let _d = armed("tier.page_in=err@1");
    let mut cfg = base_cfg(1);
    cfg.scout.tier_dram_blocks = 3;
    let pool = EnginePool::start(cfg).expect("pool start");

    // Establish "a", then demote it to the spill file by suspending "b"
    // (spill writes are healthy here — only page-in is armed).
    let pa = prompt(32, 1);
    let first = expect_done(wait_terminal(
        &pool.submit(Submission::new(pa.clone(), 6).with_session_id("a")),
    ));
    expect_done(wait_terminal(
        &pool.submit(Submission::new(prompt(32, 2), 6).with_session_id("b")),
    ));
    assert!(
        pool.stats().get("tier").unwrap().req_usize("spilled").unwrap() >= 3,
        "\"a\" must be cold before the resume"
    );

    let mut hist = pa;
    hist.extend_from_slice(&first);
    let h = pool.submit(Submission::new(hist.clone(), 6).with_session_id("a"));
    match wait_terminal(&h) {
        StreamEvent::Failed { id, error } => {
            assert_eq!(id, h.id);
            assert!(error.contains("tier page-in"), "{error}");
        }
        other => panic!("expected structured Failed, got {other:?}"),
    }
    assert_single_terminal(&h);

    // The rule is spent and the session was consumed by the failed
    // probe: the retry misses, prefills fresh, and completes.
    let retry = pool.submit(Submission::new(hist, 6).with_session_id("a"));
    expect_done(wait_terminal(&retry));
    settle(&pool, "page-in fault settlement", |s| {
        s.req_usize("inflight_tokens").unwrap() == 0
    });
    pool.shutdown().expect("shutdown");
}
