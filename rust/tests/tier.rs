//! Tiered KV store integration suite: session suspend/resume through
//! the serving plane.
//!
//! Pins the tier's end-to-end contracts:
//!
//! 1. **Exact resume is byte-identical to a continuous session**: a
//!    request suspended under a `session_id` and resumed by a follow-up
//!    whose prompt equals the stored history (prompt ++ generated)
//!    produces exactly the tokens the uninterrupted run would have —
//!    the suspended scheduler state (resident sets, selections, recall
//!    countdowns, last token) is restored, not recomputed.
//! 2. **Divergence rewinds to a fresh prefill**: a follow-up sharing
//!    only a prefix reuses the token-pure blocks and re-embeds the new
//!    prompt verbatim — byte-identical to prefilling it from scratch.
//! 3. **The default is byte-for-byte off**: with `tier_dram_blocks = 0`
//!    a `session_id` is accepted and ignored, and `{"stats":true}`
//!    reports `tier: null`.
//! 4. **Spill → page-in roundtrips**: sessions demoted to the spill
//!    file under DRAM pressure page back in bitwise (same tokens as
//!    the continuous run) and the stats counters record the traffic.

mod common;

use scoutattention::config::RunConfig;
use scoutattention::serve::{EnginePool, Submission};
use scoutattention::util::Json;

/// Deterministic prompt in test-tiny vocab (256), avoiding pad token 0.
fn prompt(len: usize, salt: u32) -> Vec<u32> {
    (0..len as u32).map(|i| 1 + (i * 29 + salt * 11) % 255).collect()
}

/// One-replica pool config with the session tier enabled.
fn tier_cfg(dram_blocks: usize) -> RunConfig {
    let mut cfg = RunConfig::for_preset(common::PRESET);
    cfg.server.replicas = 1;
    cfg.scout.tier_dram_blocks = dram_blocks;
    cfg
}

fn tier_stats(pool: &EnginePool) -> Json {
    pool.stats().get("tier").expect("tier section in stats").clone()
}

#[test]
fn exact_resume_is_byte_identical_to_continuous_session() {
    let pool = EnginePool::start(tier_cfg(64)).expect("pool start");
    let p = prompt(32, 1);

    // Continuous reference on the same pool: one uninterrupted request
    // (no session key) generating the full 16 tokens.
    let cont = pool.submit(Submission::new(p.clone(), 16)).wait().unwrap().generated;
    assert_eq!(cont.len(), 16);

    // Turn 1: first half of the session, suspended at completion.
    let first = pool
        .submit(Submission::new(p.clone(), 8).with_session_id("conv"))
        .wait()
        .unwrap()
        .generated;
    assert_eq!(first, cont[..8], "turn 1 must match the continuous prefix");
    assert!(
        pool.session_tier().expect("tier enabled").sessions() >= 1,
        "finished session must be suspended, not dropped"
    );

    // Turn 2: prompt == stored history -> exact-match decode resume.
    // The generated tokens must be the continuous run's second half.
    let mut hist = p.clone();
    hist.extend_from_slice(&first);
    let second = pool
        .submit(Submission::new(hist, 8).with_session_id("conv"))
        .wait()
        .unwrap()
        .generated;
    assert_eq!(second, cont[8..], "resumed decode diverged from the continuous session");

    let t = tier_stats(&pool);
    assert!(t.req_usize("suspended").unwrap() >= 2, "both turns suspend");
    assert_eq!(t.req_usize("resumed").unwrap(), 1, "turn 2 resumed");
    // Turn 1 probed an unknown session key: an honest miss, not an error.
    assert!(t.req_usize("misses").unwrap() >= 1);
    pool.shutdown().expect("shutdown");
}

#[test]
fn divergent_followup_matches_fresh_prefill_bytes() {
    let pool = EnginePool::start(tier_cfg(64)).expect("pool start");

    // Establish a session over a 48-token prompt (3 full blocks).
    let p1 = prompt(48, 3);
    let _ = pool
        .submit(Submission::new(p1.clone(), 6).with_session_id("edit"))
        .wait()
        .unwrap();

    // Follow-up shares the first 32 tokens then diverges (the client
    // edited its prompt): the tier rewinds to the shared block-aligned
    // token-pure prefix and the rest re-prefills with the new tokens.
    let mut p2 = p1[..32].to_vec();
    p2.extend(prompt(16, 99)); // different tail, same total length
    assert_ne!(p1, p2);
    let resumed = pool
        .submit(Submission::new(p2.clone(), 6).with_session_id("edit"))
        .wait()
        .unwrap()
        .generated;

    // Reference: the same prompt prefilled from scratch (no session).
    let fresh = pool.submit(Submission::new(p2, 6)).wait().unwrap().generated;
    assert_eq!(resumed, fresh, "divergence rewind must be invisible in the output");
    assert!(tier_stats(&pool).req_usize("resumed").unwrap() >= 1, "the rewind is a resume");
    pool.shutdown().expect("shutdown");
}

#[test]
fn extension_followup_resumes_and_is_deterministic() {
    // Two independent pools run the identical two-turn conversation with
    // extra user tokens appended in turn 2 (a forced-decode extension
    // resume); the byte streams must match across pools, and the tier
    // must actually resume rather than re-prefill.
    let run = || {
        let pool = EnginePool::start(tier_cfg(64)).expect("pool start");
        let p = prompt(32, 5);
        let first = pool
            .submit(Submission::new(p.clone(), 6).with_session_id("chat"))
            .wait()
            .unwrap()
            .generated;
        let mut turn2 = p;
        turn2.extend_from_slice(&first);
        turn2.extend(prompt(8, 77)); // the user's next message
        let second = pool
            .submit(Submission::new(turn2, 6).with_session_id("chat"))
            .wait()
            .unwrap()
            .generated;
        let t = tier_stats(&pool);
        assert_eq!(t.req_usize("resumed").unwrap(), 1, "turn 2 must resume the session");
        pool.shutdown().expect("shutdown");
        (first, second)
    };
    let (a1, a2) = run();
    let (b1, b2) = run();
    assert_eq!(a1, b1);
    assert_eq!(a2, b2, "extension resume must be deterministic");
    assert_eq!(a2.len(), 6);
}

#[test]
fn disabled_tier_ignores_session_id_byte_for_byte() {
    // Default config: tier_dram_blocks = 0. The session key must change
    // nothing — not the bytes, not the stats shape.
    let mut cfg = RunConfig::for_preset(common::PRESET);
    cfg.server.replicas = 1;
    assert_eq!(cfg.scout.tier_dram_blocks, 0, "tier must default off");
    let pool = EnginePool::start(cfg).expect("pool start");
    let p = prompt(24, 2);

    let keyless = pool.submit(Submission::new(p.clone(), 6)).wait().unwrap().generated;
    let keyed = pool
        .submit(Submission::new(p.clone(), 6).with_session_id("ignored"))
        .wait()
        .unwrap()
        .generated;
    assert_eq!(keyed, keyless, "session_id must be inert when the tier is off");
    assert!(pool.session_tier().is_none());

    // A same-key follow-up finds nothing to resume and prefills fresh —
    // same bytes as a keyless run of the full history.
    let mut hist = p;
    hist.extend_from_slice(&keyed);
    let follow = pool
        .submit(Submission::new(hist.clone(), 4).with_session_id("ignored"))
        .wait()
        .unwrap()
        .generated;
    let fresh = pool.submit(Submission::new(hist, 4)).wait().unwrap().generated;
    assert_eq!(follow, fresh);

    assert!(
        matches!(pool.stats().get("tier"), Some(Json::Null)),
        "disabled tier reports null, not zeros"
    );
    pool.shutdown().expect("shutdown");
}

#[test]
fn spilled_session_pages_back_in_bitwise() {
    // DRAM budget of 3 block-sets: one 32-token + 6-step session needs 3
    // (38 rows / 16), so suspending a second session forces the first
    // one's blocks out to the spill file. Resuming it then pages every
    // block back in — and the generated bytes must still equal the
    // continuous run's.
    let pool = EnginePool::start(tier_cfg(3)).expect("pool start");
    let pa = prompt(32, 11);
    let pb = prompt(32, 22);

    let cont = pool.submit(Submission::new(pa.clone(), 12)).wait().unwrap().generated;

    let first = pool
        .submit(Submission::new(pa.clone(), 6).with_session_id("a"))
        .wait()
        .unwrap()
        .generated;
    assert_eq!(first, cont[..6]);
    let _ = pool
        .submit(Submission::new(pb, 6).with_session_id("b"))
        .wait()
        .unwrap();
    let t = tier_stats(&pool);
    assert!(t.req_usize("spilled").unwrap() >= 3, "suspending b must demote a's blocks");
    assert!(t.req_usize("spill_file_bytes").unwrap() > 0);

    let mut hist = pa;
    hist.extend_from_slice(&first);
    let second = pool
        .submit(Submission::new(hist, 6).with_session_id("a"))
        .wait()
        .unwrap()
        .generated;
    assert_eq!(second, cont[6..], "paged-in KV diverged from the continuous session");

    let t = tier_stats(&pool);
    assert!(t.req_usize("paged_in").unwrap() >= 3, "a's cold blocks paged back in");
    assert!(
        t.get("page_in_us").unwrap().req_usize("count").unwrap() >= 3,
        "page-in latency recorded"
    );
    pool.shutdown().expect("shutdown");
}

#[test]
fn session_count_cap_evicts_lru_and_empty_key_is_rejected() {
    let mut cfg = tier_cfg(64);
    cfg.scout.tier_sessions = 2;
    let pool = EnginePool::start(cfg).expect("pool start");

    for (i, sid) in ["s0", "s1", "s2"].iter().enumerate() {
        let _ = pool
            .submit(Submission::new(prompt(16, i as u32), 4).with_session_id(*sid))
            .wait()
            .unwrap();
    }
    let t = tier_stats(&pool);
    assert_eq!(t.req_usize("sessions").unwrap(), 2, "cap holds");
    assert!(t.req_usize("evicted").unwrap() >= 1, "LRU session evicted at the cap");

    // Wire validation: an empty session key is a client error, answered
    // as a structured rejection before any placement.
    let h = pool.submit(Submission::new(prompt(8, 9), 2).with_session_id(""));
    assert!(h.wait().is_err(), "empty session_id must be rejected");
    pool.shutdown().expect("shutdown");
}
