//! Head-wise offload granularity (`scout.head_groups`) — integration
//! pins.
//!
//! Three byte-identity contracts and two behavior contracts:
//!
//! 1. **Variable-tile decode == padded decode.** The decode loop now
//!    routes partial batches through row-wise tiles instead of padding
//!    to the artifact batch size. `force_padded_decode` replays the
//!    pre-change padded execution; both paths must emit identical
//!    tokens (the kernels are row-wise, so per-row numerics cannot
//!    depend on the tile height).
//! 2. **`head_groups = 1` is the pre-change scheduler.** A non-divisor
//!    group count must clamp to the effective single-group path and
//!    reproduce its token stream byte-for-byte.
//! 3. **Handoff export/import preserves per-group resident state.** A
//!    mid-decode `into_handoff` -> `from_handoff` roundtrip must keep
//!    every group's visible set, capacity, and classifier verdict, and
//!    the continued decode must match an uninterrupted run exactly —
//!    at one group and at `head_groups = n_kv_heads`.
//!
//! Behavior: grouped runs report per-group stats and keep per-group
//! selection shapes (`selected[layer].len() == head_groups`).

mod common;

use scoutattention::coordinator::{
    Batch, DecodeScheduler, RecallController, RequestSpec, ScoutScheduler, SeqState,
};
use scoutattention::harness::{self, ServingRun, Stack};

fn prompt(len: usize, salt: u32) -> Vec<u32> {
    (0..len as u32).map(|i| 1 + (i * 13 + salt * 5) % 255).collect()
}

/// Mixed-length requests: 2 admit immediately (max_batch = 2), the
/// third queues; staggered finishes leave a 1-row partial tile phase at
/// the end — the case variable-tile decode exists for.
fn requests(bs: usize) -> Vec<RequestSpec> {
    vec![
        RequestSpec::new(0, prompt(3 * bs + 5, 1), 10),
        RequestSpec::new(1, prompt(2 * bs + 1, 2), 16),
        RequestSpec::new(2, prompt(4 * bs, 3), 4),
    ]
}

fn scout(stack: &Stack, head_groups: usize, force_padded: bool) -> ScoutScheduler {
    let mut cfg = stack.cfg.scout.clone();
    cfg.head_groups = head_groups;
    // Chunked prefill on admission so the identity runs cover it too.
    cfg.prefill_chunk = stack.gpu.spec.block_size;
    let recall = RecallController::new(&cfg, stack.gpu.spec.n_layers, None);
    let mut s = ScoutScheduler::new(stack.gpu.clone(), stack.native.clone(), cfg, recall);
    s.force_padded_decode = force_padded;
    s
}

fn run_scout(stack: &Stack, head_groups: usize, force_padded: bool) -> ServingRun {
    let mut sched = scout(stack, head_groups, force_padded);
    let mut batch = stack.batch();
    harness::run_serving(&mut sched, &mut batch, requests(stack.gpu.spec.block_size), 10_000)
        .expect("serving run")
}

fn tokens(run: &ServingRun) -> Vec<(u64, Vec<u32>)> {
    run.outputs.iter().map(|o| (o.id, o.generated.clone())).collect()
}

#[test]
fn variable_tile_decode_matches_forced_padded_path() {
    let stack = common::stack();
    let flex = run_scout(&stack, 1, false);
    let padded = run_scout(&stack, 1, true);
    for run in [&flex, &padded] {
        assert_eq!(run.outputs.len(), 3, "all requests finish");
        for o in &run.outputs {
            assert!(!o.generated.is_empty(), "request {} generated nothing", o.id);
        }
    }
    assert_eq!(
        tokens(&flex),
        tokens(&padded),
        "variable-tile decode must be byte-identical to the padded pre-change path"
    );
}

#[test]
fn non_divisor_head_groups_clamps_to_single_group_byte_identically() {
    let stack = common::stack();
    let hkv = stack.gpu.spec.n_kv_heads;
    let bad = hkv + 1; // never divides n_kv_heads
    assert!(hkv % bad != 0);
    let base = run_scout(&stack, 1, false);
    let clamped = run_scout(&stack, bad, false);
    assert_eq!(
        tokens(&base),
        tokens(&clamped),
        "a non-divisor head_groups must fall back to the single-group path"
    );
    assert!(
        clamped.stats.iter().all(|s| s.head_groups == 1),
        "clamped run must report effective head_groups = 1"
    );
    assert!(
        clamped.stats.iter().all(|s| s.pinned_groups == 0 && s.offloaded_groups == 0),
        "single-group path never runs the heavy-hitter classifier"
    );
}

#[test]
fn grouped_run_finishes_and_reports_group_stats() {
    let stack = common::stack();
    let g = stack.gpu.spec.n_kv_heads;
    assert!(g > 1, "test-tiny must have multiple KV heads for this suite");
    let run = run_scout(&stack, g, false);
    assert_eq!(run.outputs.len(), 3, "grouped run must finish all requests");
    for (req, o) in requests(stack.gpu.spec.block_size).iter().zip(&run.outputs) {
        assert_eq!(o.generated.len(), req.max_new_tokens, "request {} truncated", o.id);
    }
    assert!(
        run.stats.iter().all(|s| s.head_groups == g),
        "every step must report the effective group count"
    );
    let observed: usize = run.stats.iter().map(|s| s.pinned_groups + s.offloaded_groups).sum();
    assert!(observed > 0, "grouped selection must classify groups");
}

/// Snapshot of one sequence's grouped scheduler state (what a handoff
/// must preserve bit-for-bit).
#[allow(clippy::type_complexity)]
fn resident_snapshot(seq: &SeqState) -> Vec<Vec<(Vec<usize>, usize, bool)>> {
    seq.resident
        .iter()
        .map(|r| {
            (0..r.n_groups())
                .map(|grp| {
                    (r.iter_group(grp).collect(), r.capacity_group(grp), r.pinned_dense(grp))
                })
                .collect()
        })
        .collect()
}

fn drive(sched: &mut ScoutScheduler, batch: &mut Batch, steps: usize) {
    for _ in 0..steps {
        if batch.live() == 0 {
            break;
        }
        sched.step(batch).expect("decode step");
        batch.reap();
    }
}

fn finished_tokens(batch: &mut Batch) -> Vec<(u64, Vec<u32>)> {
    while let Some(s) = batch.seqs.pop() {
        batch.finished.push(s.finish());
    }
    let mut out: Vec<(u64, Vec<u32>)> =
        batch.finished.iter().map(|o| (o.id, o.generated.clone())).collect();
    out.sort_by_key(|(id, _)| *id);
    out
}

fn handoff_roundtrip_preserves_state(head_groups: usize) {
    let stack = common::stack();
    let spec = stack.gpu.spec.clone();
    let reqs = vec![
        RequestSpec::new(0, prompt(3 * spec.block_size, 4), 12),
        RequestSpec::new(1, prompt(2 * spec.block_size + 7, 5), 12),
    ];

    // Reference: uninterrupted decode to completion.
    let mut sched_a = scout(&stack, head_groups, false);
    let mut batch_a = stack.batch();
    for r in &reqs {
        sched_a.admit(&mut batch_a, r).expect("admit");
    }
    drive(&mut sched_a, &mut batch_a, 64);
    let reference = finished_tokens(&mut batch_a);

    // Roundtrip arm: decode 5 steps, migrate every sequence through the
    // handoff bundle, continue to completion.
    let mut sched_b = scout(&stack, head_groups, false);
    let mut batch_b = stack.batch();
    for r in &reqs {
        sched_b.admit(&mut batch_b, r).expect("admit");
    }
    drive(&mut sched_b, &mut batch_b, 5);
    assert_eq!(batch_b.live(), 2, "nothing finishes within 5 of 12 steps");

    let g = sched_b.head_groups();
    let migrated: Vec<SeqState> = batch_b
        .seqs
        .drain(..)
        .map(|seq| {
            let before = resident_snapshot(&seq);
            let h = seq.into_handoff();
            for (l, r) in h.resident.iter().enumerate() {
                assert_eq!(r.n_groups(), g, "layer {l}: handoff must carry every group");
                assert_eq!(h.selected[l].len(), g, "layer {l}: per-group selection shape");
            }
            let seq = SeqState::from_handoff(h).expect("import handoff");
            assert_eq!(
                resident_snapshot(&seq),
                before,
                "grouped resident state must survive export/import"
            );
            seq
        })
        .collect();
    for seq in migrated {
        batch_b.activate(seq).expect("re-activate");
    }
    drive(&mut sched_b, &mut batch_b, 64);

    assert_eq!(
        reference,
        finished_tokens(&mut batch_b),
        "decode after a handoff roundtrip must be byte-identical (head_groups = {head_groups})"
    );
}

#[test]
fn handoff_roundtrip_is_byte_identical_at_one_group() {
    handoff_roundtrip_preserves_state(1);
}

#[test]
fn handoff_roundtrip_is_byte_identical_per_head_group() {
    let g = common::stack().gpu.spec.n_kv_heads;
    handoff_roundtrip_preserves_state(g);
}

#[test]
fn grouped_selection_keeps_per_group_shape() {
    let stack = common::stack();
    let spec = stack.gpu.spec.clone();
    let g = spec.n_kv_heads;
    let mut sched = scout(&stack, g, false);
    let mut batch = stack.batch();
    let req = RequestSpec::new(0, prompt(4 * spec.block_size, 9), 8);
    sched.admit(&mut batch, &req).expect("admit");
    drive(&mut sched, &mut batch, 4);
    assert_eq!(batch.live(), 1);
    let seq = &batch.seqs[0];
    let nb = spec.n_blocks();
    for (l, (sel, res)) in seq.selected.iter().zip(&seq.resident).enumerate() {
        assert_eq!(sel.len(), g, "layer {l}: one selection list per group");
        assert_eq!(res.n_groups(), g, "layer {l}: one residency per group");
        assert!(
            sel.iter().any(|s| !s.is_empty()),
            "layer {l}: grouped selection must pick blocks"
        );
        for (grp, s) in sel.iter().enumerate() {
            assert!(
                s.iter().all(|&b| b < nb),
                "layer {l} group {grp}: selected block out of range"
            );
        }
        // Scores are stored group-major: g contiguous per-group rows.
        assert_eq!(seq.scores(l).len() % g, 0, "layer {l}: scores not group-major");
        assert!(!seq.scores(l).is_empty(), "layer {l}: grouped scoring ran");
    }
}
