//! Serving-plane integration: the multi-replica engine pool driven both
//! in-process (submit/stream API) and over the JSON-lines TCP front-end
//! (test-tiny preset, interpreter backend — no artifacts required).
//!
//! Covers the serving contracts: concurrent multi-client decode across
//! replicas, streaming order + parity with the single-shot path,
//! bounded + observable backpressure, wire-boundary validation, and
//! graceful drain.

mod common;

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Duration;

use scoutattention::config::{Method, RunConfig};
use scoutattention::coordinator::{RequestOutput, RequestSpec};
use scoutattention::harness;
use scoutattention::serve::{EnginePool, RejectCode, StreamEvent, StreamHandle, Submission};
use scoutattention::util::Json;

const WAIT: Duration = Duration::from_secs(120);

fn pool_cfg() -> RunConfig {
    RunConfig::for_preset(common::PRESET)
}

/// Drain a handle with a timeout so a regression fails instead of
/// hanging the suite. Returns the terminal event.
fn wait_terminal(h: &StreamHandle) -> StreamEvent {
    loop {
        match h.recv_timeout(WAIT) {
            Some(ev @ StreamEvent::Done(_))
            | Some(ev @ StreamEvent::Rejected(_))
            | Some(ev @ StreamEvent::Cancelled { .. })
            | Some(ev @ StreamEvent::Failed { .. })
            | Some(ev @ StreamEvent::ReplicaLost { .. })
            | Some(ev @ StreamEvent::DeadlineExceeded { .. }) => return ev,
            Some(StreamEvent::Token { .. }) => continue,
            None => panic!("stream closed without a terminal event"),
        }
    }
}

fn expect_done(ev: StreamEvent) -> RequestOutput {
    match ev {
        StreamEvent::Done(out) => out,
        other => panic!("expected Done, got {other:?}"),
    }
}

/// Deterministic prompt in test-tiny vocab (256), avoiding pad token 0.
fn prompt(len: usize, salt: u32) -> Vec<u32> {
    (0..len as u32).map(|i| 1 + (i * 31 + salt * 7) % 255).collect()
}

#[test]
fn pool_multi_replica_matches_single_shot() {
    let mut cfg = pool_cfg();
    cfg.server.replicas = 2;
    cfg.server.max_batch = 2;
    let pool = EnginePool::start(cfg.clone()).expect("pool start");
    assert_eq!(pool.replica_count(), 2);

    // Mixed-length prompts, half streaming, submitted concurrently.
    let prompts: Vec<Vec<u32>> = (0..6).map(|i| prompt(32 + 16 * (i % 3), i as u32)).collect();
    let new_tokens = 6usize;
    let handles: Vec<StreamHandle> = prompts
        .iter()
        .enumerate()
        .map(|(i, p)| {
            let mut sub = Submission::new(p.clone(), new_tokens);
            if i % 2 == 0 {
                sub = sub.streaming();
            }
            pool.submit(sub)
        })
        .collect();
    let mut outputs: Vec<RequestOutput> =
        handles.iter().map(|h| expect_done(wait_terminal(h))).collect();
    outputs.sort_by_key(|o| o.id);
    assert_eq!(outputs.len(), 6);
    for (i, o) in outputs.iter().enumerate() {
        assert_eq!(o.id, i as u64, "pool ids are assigned in submit order");
        assert_eq!(o.generated.len(), new_tokens);
        assert!(o.ttft_us > 0, "TTFT must be measurable through the pool");
    }

    // Single-shot reference: each request decoded alone on a fresh batch.
    let stack = harness::Stack::load(&cfg).expect("reference stack");
    for (i, p) in prompts.iter().enumerate() {
        let reqs = vec![RequestSpec::new(0, p.clone(), new_tokens)];
        let reference = harness::run_method(&stack, Method::Scout, reqs, 1000, None).unwrap();
        assert_eq!(
            outputs[i].generated, reference.outputs[0].generated,
            "request {i}: pooled decode must match the single-shot path"
        );
    }
    pool.shutdown().expect("shutdown");
}

#[test]
fn streaming_orders_tokens_and_matches_non_streaming() {
    let mut cfg = pool_cfg();
    cfg.server.replicas = 1;
    let pool = EnginePool::start(cfg).expect("pool start");
    let p = prompt(48, 3);

    let h = pool.submit(Submission::new(p.clone(), 8).streaming());
    let mut streamed = Vec::new();
    let mut steps = Vec::new();
    let final_out;
    loop {
        match h.recv_timeout(WAIT).expect("stream event") {
            StreamEvent::Token { token, step, .. } => {
                streamed.push(token);
                steps.push(step);
            }
            StreamEvent::Done(out) => {
                final_out = out;
                break;
            }
            other => panic!("unexpected event {other:?}"),
        }
    }
    assert_eq!(steps, (1..=8).collect::<Vec<_>>(), "tokens must arrive in step order");
    assert_eq!(streamed, final_out.generated, "streamed tokens must equal the final output");

    let out2 = expect_done(wait_terminal(&pool.submit(Submission::new(p, 8))));
    assert_eq!(
        out2.generated, final_out.generated,
        "streaming and non-streaming paths must be byte-identical"
    );
    pool.shutdown().expect("shutdown");
}

#[test]
fn backpressure_is_bounded_and_observable() {
    let mut cfg = pool_cfg();
    cfg.server.replicas = 1;
    cfg.server.max_batch = 1;
    cfg.server.queue_depth = 1;
    let pool = EnginePool::start(cfg).expect("pool start");

    // A saturates the single batch slot; wait for its first token so it
    // is live (and the bounded channel is empty again). Its decode
    // budget is large enough that it cannot finish while this thread
    // submits B/C and snapshots stats, even under heavy CI preemption.
    let a = pool.submit(Submission::new(prompt(32, 1), 200).streaming());
    match a.recv_timeout(WAIT) {
        Some(StreamEvent::Token { .. }) => {}
        other => panic!("expected first token from A, got {other:?}"),
    }
    // B fills the queue_depth=1 channel; C must be rejected, structured.
    let b = pool.submit(Submission::new(prompt(32, 2), 2));
    let c = pool.submit(Submission::new(prompt(32, 3), 2));
    match wait_terminal(&c) {
        StreamEvent::Rejected(r) => {
            assert_eq!(r.code, RejectCode::Overloaded);
            assert!(r.retry_after_ms > 0, "backpressure must carry a retry hint");
            assert!(r.reason.contains("queue full"), "{}", r.reason);
        }
        other => panic!("expected C rejected, got {other:?}"),
    }

    // Queue depth and rejects are visible in the stats snapshot.
    let stats = pool.stats();
    assert!(stats.req_usize("rejected").unwrap() >= 1);
    assert!(
        stats.get("rejected_by").unwrap().req_usize("overloaded").unwrap() >= 1,
        "rejects must be classified"
    );
    assert_eq!(stats.req_usize("queue_depth").unwrap(), 1, "B still queued");

    // Nothing hangs: A and B both complete.
    expect_done(wait_terminal(&a));
    expect_done(wait_terminal(&b));
    pool.shutdown().expect("shutdown");
}

#[test]
fn cancellation_frees_the_batch_slot() {
    let mut cfg = pool_cfg();
    cfg.server.replicas = 1;
    cfg.server.max_batch = 1;
    let pool = EnginePool::start(cfg).expect("pool start");

    // A would hold the only slot for 200 steps; cancel it after the
    // first token and B must still complete promptly.
    let a = pool.submit(Submission::new(prompt(32, 1), 200).streaming());
    match a.recv_timeout(WAIT) {
        Some(StreamEvent::Token { .. }) => {}
        other => panic!("expected first token from A, got {other:?}"),
    }
    pool.cancel(&a);
    let b = pool.submit(Submission::new(prompt(32, 2), 2));
    let out = expect_done(wait_terminal(&b));
    assert_eq!(out.generated.len(), 2);
    // A's stream ends with the *distinct* cancellation terminal (not a
    // Failed): clients and telemetry can tell a hangup from a fault.
    match wait_terminal(&a) {
        StreamEvent::Cancelled { id } => assert_eq!(id, a.id),
        other => panic!("expected A cancelled, got {other:?}"),
    }
    let stats = pool.stats();
    assert!(stats.req_usize("cancelled").unwrap() >= 1);
    assert_eq!(
        stats.get("replicas").unwrap().as_arr().unwrap()[0].req_usize("failed").unwrap(),
        0,
        "cancellation must not count as a failure"
    );
    pool.shutdown().expect("shutdown");
}

#[test]
fn token_budget_rejects_before_queueing() {
    let mut cfg = pool_cfg();
    cfg.server.token_budget = 8;
    let pool = EnginePool::start(cfg).expect("pool start");
    let h = pool.submit(Submission::new(prompt(16, 1), 4)); // cost 20 > 8
    match wait_terminal(&h) {
        StreamEvent::Rejected(r) => {
            assert_eq!(r.code, RejectCode::Overloaded);
            assert!(r.reason.contains("token budget"), "{}", r.reason);
        }
        other => panic!("expected budget rejection, got {other:?}"),
    }
    pool.shutdown().expect("shutdown");
}

#[test]
fn wire_validation_rejects_impossible_requests() {
    let pool = EnginePool::start(pool_cfg()).expect("pool start");
    let max_seq = pool.spec().max_seq;

    // context overflow: can never be served
    let h = pool.submit(Submission::new(prompt(max_seq, 1), 8));
    match wait_terminal(&h) {
        StreamEvent::Rejected(r) => {
            assert_eq!(r.code, RejectCode::Invalid);
            assert!(r.reason.contains("context overflow"), "{}", r.reason);
            assert_eq!(r.retry_after_ms, 0, "retrying an invalid request cannot help");
        }
        other => panic!("expected invalid rejection, got {other:?}"),
    }
    // zero decode budget
    let h = pool.submit(Submission::new(prompt(8, 1), 0));
    match wait_terminal(&h) {
        StreamEvent::Rejected(r) => assert_eq!(r.code, RejectCode::Invalid),
        other => panic!("expected invalid rejection, got {other:?}"),
    }
    // absurd decode budget must reject cleanly, not overflow the
    // context arithmetic
    let h = pool.submit(Submission::new(prompt(8, 1), usize::MAX));
    match wait_terminal(&h) {
        StreamEvent::Rejected(r) => assert_eq!(r.code, RejectCode::Invalid),
        other => panic!("expected invalid rejection, got {other:?}"),
    }
    // out-of-vocab token id
    let h = pool.submit(Submission::new(vec![9999], 2));
    match wait_terminal(&h) {
        StreamEvent::Rejected(r) => {
            assert_eq!(r.code, RejectCode::Invalid);
            assert!(r.reason.contains("vocab"), "{}", r.reason);
        }
        other => panic!("expected invalid rejection, got {other:?}"),
    }
    let stats = pool.stats();
    assert!(stats.get("rejected_by").unwrap().req_usize("invalid").unwrap() >= 3);
    pool.shutdown().expect("shutdown");
}

#[test]
fn drain_finishes_accepted_work_then_refuses() {
    let mut cfg = pool_cfg();
    cfg.server.replicas = 2;
    let pool = EnginePool::start(cfg).expect("pool start");
    let handles: Vec<StreamHandle> =
        (0..4).map(|i| pool.submit(Submission::new(prompt(32, i), 5))).collect();
    // Drain immediately: everything accepted must still complete.
    pool.shutdown().expect("shutdown");
    for h in &handles {
        let out = expect_done(wait_terminal(h));
        assert_eq!(out.generated.len(), 5);
    }
    let late = pool.submit(Submission::new(prompt(8, 9), 2));
    match wait_terminal(&late) {
        StreamEvent::Rejected(r) => assert_eq!(r.code, RejectCode::Draining),
        other => panic!("expected draining rejection, got {other:?}"),
    }
}

#[test]
fn serve_roundtrip_over_tcp() {
    let mut cfg = pool_cfg();
    cfg.server.listen = "127.0.0.1:17431".to_string();
    cfg.server.replicas = 2;
    let server = std::thread::spawn(move || scoutattention::server::serve(cfg));

    let mut sock = None;
    for _ in 0..100 {
        match TcpStream::connect("127.0.0.1:17431") {
            Ok(s) => {
                sock = Some(s);
                break;
            }
            Err(_) => std::thread::sleep(Duration::from_millis(100)),
        }
    }
    let sock = sock.expect("server did not come up");
    let mut reader = BufReader::new(sock.try_clone().unwrap());
    let mut w = sock;
    let read_json = |reader: &mut BufReader<TcpStream>| -> Json {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        Json::parse(&line).unwrap_or_else(|e| panic!("bad json {line:?}: {e}"))
    };

    // malformed line gets an error object, not a hangup
    writeln!(w, "this is not json").unwrap();
    assert!(read_json(&mut reader).get("error").is_some());

    // non-streaming request: one terminal line with timing fields
    writeln!(w, "{{\"prompt\":[5,6,7,8,9,10,11,12], \"max_new_tokens\": 4}}").unwrap();
    let j = read_json(&mut reader);
    assert_eq!(j.req("generated").unwrap().as_arr().unwrap().len(), 4);
    assert_eq!(j.req_usize("steps").unwrap(), 4);
    assert!(j.req_usize("ttft_us").unwrap() > 0, "{j:?}");

    // streaming request: per-step token lines, then the terminal line
    writeln!(w, "{{\"prompt\":[1,2,3,4], \"max_new_tokens\": 3, \"stream\": true}}").unwrap();
    let mut tokens = Vec::new();
    let terminal = loop {
        let j = read_json(&mut reader);
        if let Some(t) = j.get("token") {
            assert_eq!(j.req_usize("step").unwrap(), tokens.len() + 1, "step order");
            tokens.push(t.as_u64().unwrap() as u32);
        } else {
            break j;
        }
    };
    assert_eq!(tokens.len(), 3);
    let final_gen: Vec<u32> = terminal
        .req("generated")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_u64().unwrap() as u32)
        .collect();
    assert_eq!(tokens, final_gen);

    // over-context request is refused with a structured error
    let long: Vec<String> = (0..400).map(|i| (1 + i % 200).to_string()).collect();
    writeln!(w, "{{\"prompt\":[{}], \"max_new_tokens\": 4}}", long.join(",")).unwrap();
    let j = read_json(&mut reader);
    assert_eq!(j.req_str("code").unwrap(), "invalid", "{j:?}");
    assert!(j.get("error").is_some());

    // stats control request
    writeln!(w, "{{\"stats\": true}}").unwrap();
    let stats = read_json(&mut reader);
    assert_eq!(stats.req_usize("replica_count").unwrap(), 2);
    assert!(stats.get("replicas").unwrap().as_arr().unwrap().len() == 2);
    assert!(stats.req_usize("tokens_out").unwrap() >= 7);
    assert!(stats.get("ttft_us").unwrap().get("p50").is_some());

    // graceful shutdown: drain + listener exit
    writeln!(w, "{{\"shutdown\": true}}").unwrap();
    let j = read_json(&mut reader);
    assert_eq!(j.get("ok").unwrap().as_bool(), Some(true));
    server.join().unwrap().expect("serve() returns cleanly after drain");
}
