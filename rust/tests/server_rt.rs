//! Server integration: spin the JSON-lines TCP server on the test-tiny
//! preset (interpreter backend — no artifacts required) and drive it from
//! a client socket — the full python-free request path (admission ->
//! prefill -> scout decode -> response).

mod common;

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use scoutattention::config::RunConfig;
use scoutattention::util::Json;

#[test]
fn serve_roundtrip_over_tcp() {
    let mut cfg = RunConfig::for_preset(common::PRESET);
    cfg.server.listen = "127.0.0.1:17411".to_string();
    std::thread::spawn(move || {
        let _ = scoutattention::server::serve(cfg);
    });

    // wait for the listener (engine loads artifacts lazily, bind is fast)
    let mut sock = None;
    for _ in 0..100 {
        match TcpStream::connect("127.0.0.1:17411") {
            Ok(s) => {
                sock = Some(s);
                break;
            }
            Err(_) => std::thread::sleep(std::time::Duration::from_millis(100)),
        }
    }
    let sock = sock.expect("server did not come up");
    let mut reader = BufReader::new(sock.try_clone().unwrap());
    let mut w = sock;

    // malformed line gets an error object, not a hangup
    writeln!(w, "this is not json").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(Json::parse(&line).unwrap().get("error").is_some(), "{line}");

    // real request
    writeln!(w, "{{\"prompt\":[5,6,7,8,9,10,11,12], \"max_new_tokens\": 4}}").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let j = Json::parse(&line).unwrap();
    let gen = j.req("generated").unwrap().as_arr().unwrap();
    assert_eq!(gen.len(), 4, "{line}");
    assert_eq!(j.req_usize("steps").unwrap(), 4);

    // second request on the same connection (engine keeps serving)
    writeln!(w, "{{\"prompt\":[1,2,3,4], \"max_new_tokens\": 2}}").unwrap();
    let mut line2 = String::new();
    reader.read_line(&mut line2).unwrap();
    let j2 = Json::parse(&line2).unwrap();
    assert_eq!(j2.req("generated").unwrap().as_arr().unwrap().len(), 2);
}
