//! Shared helpers for integration tests.
#![allow(dead_code)] // each test binary uses a different subset
//!
//! The stack loads through `Runtime::load_with(.., BackendKind::Auto)`:
//! with default features that is the pure-rust interpreter backend (its
//! manifest is synthesized from the built-in `test-tiny` preset), so the
//! suite runs real decode steps with no `make artifacts` and no python.
//! When artifacts *are* on disk and the crate is built with
//! `--features pjrt`, the same tests exercise the PJRT path instead.

use std::sync::Arc;

use scoutattention::config::RunConfig;
use scoutattention::harness::Stack;

pub const PRESET: &str = "test-tiny";

/// Load the test stack (never skips — the interpreter backend needs no
/// on-disk artifacts).
pub fn stack() -> Arc<Stack> {
    let cfg = RunConfig::for_preset(PRESET);
    Arc::new(Stack::load(&cfg).expect("load test-tiny stack"))
}

pub fn assert_close(a: &[f32], b: &[f32], rtol: f32, atol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let tol = atol + rtol * y.abs().max(x.abs());
        assert!(
            (x - y).abs() <= tol,
            "{what}: idx {i}: {x} vs {y} (tol {tol})"
        );
    }
}

/// Max relative error helper for reporting.
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}
