//! Shared helpers for integration tests.
#![allow(dead_code)] // each test binary uses a different subset
//!
//! Tests that execute AOT artifacts require `make artifacts` to have run;
//! `stack()` panics with a clear message if the test-tiny artifact set is
//! missing (CI runs `make artifacts` first, see Makefile `test`).

use std::sync::Arc;

use scoutattention::config::RunConfig;
use scoutattention::harness::Stack;

pub const PRESET: &str = "test-tiny";

pub fn artifacts_present() -> bool {
    std::path::Path::new("artifacts/test-tiny/manifest.json").exists()
}

/// Load the test stack, or None when artifacts are absent (unit-only CI).
pub fn try_stack() -> Option<Arc<Stack>> {
    if !artifacts_present() {
        eprintln!("SKIP: artifacts/test-tiny missing — run `make artifacts`");
        return None;
    }
    let cfg = RunConfig::for_preset(PRESET);
    Some(Arc::new(Stack::load(&cfg).expect("load test-tiny stack")))
}

pub fn assert_close(a: &[f32], b: &[f32], rtol: f32, atol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let tol = atol + rtol * y.abs().max(x.abs());
        assert!(
            (x - y).abs() <= tol,
            "{what}: idx {i}: {x} vs {y} (tol {tol})"
        );
    }
}

/// Max relative error helper for reporting.
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}
