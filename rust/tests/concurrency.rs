//! Concurrency regression suite for the serving plane's channel and
//! flag disciplines, against the *real* `EnginePool` (the abstract
//! schedule-exploration of the same protocols lives in
//! `model_protocols.rs`).
//!
//! Pins three contracts:
//!
//! 1. **Handoff-channel drop discipline**: decode-role replicas park in
//!    a blocking `recv` on their handoff channel; the only thing that
//!    can wake an idle one is the disconnect cascade that starts when
//!    prefill-role replicas drop their senders at drain. A pool shut
//!    down with replicas parked like this must join, not hang.
//! 2. **Cancellation across handoff**: the request's `Arc<AtomicBool>`
//!    cancel flag travels with its track through the handoff channel,
//!    so a cancel raised while the sequence migrates prefill→decode is
//!    observed by whichever replica owns it — exactly one terminal
//!    event, budget freed, pool still drains.
//! 3. **Drain under concurrent submitters**: begin_drain racing a
//!    burst of submissions never strands a client (every handle gets a
//!    terminal event) and never wedges the join.
//! 4. **Session-tier churn**: concurrent multi-turn sessions against a
//!    DRAM budget too small for even two of them force suspends, LRU
//!    demotions, spill writes, page-ins, and session evictions to race;
//!    every turn must still reach exactly one terminal, every probe is
//!    answered exactly once, and the pool must drain to zero inflight.
//!
//! Every blocking wait is bounded so a regression fails the suite
//! instead of hanging it.

mod common;

use std::time::Duration;

use scoutattention::config::{ReplicaRole, RunConfig};
use scoutattention::coordinator::{PrefillParams, PrefillState, RequestSpec};
use scoutattention::kvcache::{chain_hash, PrefixPool, CHAIN_SEED};
use scoutattention::serve::{EnginePool, StreamEvent, StreamHandle, Submission};

const WAIT: Duration = Duration::from_secs(120);

fn pool_cfg() -> RunConfig {
    RunConfig::for_preset(common::PRESET)
}

/// Deterministic prompt in test-tiny vocab (256), avoiding pad token 0.
fn prompt(len: usize, salt: u32) -> Vec<u32> {
    (0..len as u32).map(|i| 1 + (i * 37 + salt * 13) % 255).collect()
}

fn wait_terminal(h: &StreamHandle) -> StreamEvent {
    loop {
        match h.recv_timeout(WAIT) {
            Some(StreamEvent::Token { .. }) => continue,
            Some(ev) => return ev,
            None => panic!("stream closed without a terminal event"),
        }
    }
}

/// Run a closure on another thread with a deadline: the harness for
/// asserting "this must not deadlock". `join` on a wedged pool would
/// hang the suite; this converts the hang into a test failure.
fn must_finish_within(what: &str, limit: Duration, f: impl FnOnce() + Send + 'static) {
    let (tx, rx) = std::sync::mpsc::channel();
    let t = std::thread::spawn(move || {
        f();
        let _ = tx.send(());
    });
    match rx.recv_timeout(limit) {
        Ok(()) => {
            t.join().expect("worker panicked");
        }
        Err(_) => panic!("{what}: did not finish within {limit:?} (deadlock?)"),
    }
}

/// An idle role-split pool has every decode replica parked in a
/// blocking handoff `recv` with nothing in flight. Shutdown must wake
/// them purely via the sender-drop disconnect cascade.
#[test]
fn idle_decode_replicas_wake_on_sender_drop() {
    let mut cfg = pool_cfg();
    cfg.server.replicas = 3;
    cfg.server.roles =
        vec![ReplicaRole::Prefill, ReplicaRole::Decode, ReplicaRole::Decode];
    let pool = EnginePool::start(cfg).expect("pool start");
    // Give the decode replicas time to reach the parked recv (they park
    // immediately, but don't let a slow spawn mask a wakeup bug).
    std::thread::sleep(Duration::from_millis(50));
    must_finish_within("idle role-split shutdown", WAIT, move || {
        pool.shutdown().expect("clean join");
    });
}

/// Same discipline under load: requests mid-flight through the handoff
/// plane when the drain starts. Every accepted request must still reach
/// its terminal event and the join must complete.
#[test]
fn drain_with_inflight_handoffs_joins_and_answers_everyone() {
    let mut cfg = pool_cfg();
    cfg.server.replicas = 3;
    cfg.server.roles =
        vec![ReplicaRole::Prefill, ReplicaRole::Decode, ReplicaRole::Decode];
    cfg.scout.prefill_chunk = 4; // several chunks: wide in-flight window
    let pool = EnginePool::start(cfg).expect("pool start");
    let handles: Vec<StreamHandle> = (0..6)
        .map(|i| pool.submit(Submission::new(prompt(24, i), 4)))
        .collect();
    pool.begin_drain();
    for h in &handles {
        match wait_terminal(h) {
            StreamEvent::Done(_) | StreamEvent::Rejected(_) => {}
            other => panic!("drain must complete or reject, got {other:?}"),
        }
    }
    must_finish_within("drain with in-flight handoffs", WAIT, move || {
        pool.shutdown().expect("clean join");
    });
}

/// The cancel flag is shared state that crosses the handoff channel
/// inside the track: cancelling at staggered points around the
/// prefill→decode migration must always yield exactly one terminal
/// event per request, and the pool must still drain to zero inflight
/// tokens (every reservation released exactly once).
#[test]
fn cancel_is_observed_across_handoff() {
    let mut cfg = pool_cfg();
    cfg.server.replicas = 2;
    cfg.server.roles = vec![ReplicaRole::Prefill, ReplicaRole::Decode];
    cfg.scout.prefill_chunk = 1; // many chunks: cancels land at many
                                 // points of the migration window
    let pool = EnginePool::start(cfg).expect("pool start");

    let n = 8usize;
    let handles: Vec<StreamHandle> = (0..n)
        .map(|i| pool.submit(Submission::new(prompt(20, i as u32), 6).streaming()))
        .collect();
    // Stagger the cancels so they land before, during, and after the
    // handoff for different requests.
    for (i, h) in handles.iter().enumerate() {
        std::thread::sleep(Duration::from_millis(2 * i as u64));
        pool.cancel(h);
    }
    let mut terminals = 0usize;
    for h in &handles {
        match wait_terminal(h) {
            // Any terminal is legal depending on where the cancel
            // landed; what is illegal is a second one or none.
            StreamEvent::Cancelled { .. }
            | StreamEvent::Done(_)
            | StreamEvent::Rejected(_) => terminals += 1,
            StreamEvent::Failed { id, error } => {
                panic!("request {id} failed instead of cancelling: {error}")
            }
            StreamEvent::ReplicaLost { id, .. } => {
                panic!("request {id} lost its replica with no faults armed")
            }
            StreamEvent::DeadlineExceeded { id, .. } => {
                panic!("request {id} hit a deadline it never set")
            }
            StreamEvent::Token { .. } => unreachable!(),
        }
        // The stream must be closed after its terminal: a second
        // terminal event would mean a double-termination bug.
        assert!(
            h.recv_timeout(Duration::from_millis(20)).is_none(),
            "event after terminal"
        );
    }
    assert_eq!(terminals, n);
    pool.shutdown().expect("clean join");
    // All reservations released: the drained pool reports zero inflight.
    let stats = pool.stats();
    let inflight = stats.req_usize("inflight_tokens").expect("inflight_tokens in stats");
    assert_eq!(
        inflight,
        0,
        "cancel across handoff leaked budget: {}",
        stats.to_string()
    );
}

/// Copy-on-write discipline under real thread interleaving: N prefills
/// importing the same published prefix blocks run concurrently and then
/// diverge. A write leaking through a shared `Arc` (instead of copying)
/// would scribble one sequence's tail into another's prefix; byte
/// equality against N independent cold runs rules that out. Afterwards,
/// dropping the importers must return every published block to the
/// pool's own single hold — a higher refcount is a leak that would make
/// those blocks permanently unevictable.
#[test]
fn concurrent_shared_prefix_imports_match_cold_runs_and_release_blocks() {
    fn params(n_layers: usize) -> PrefillParams {
        PrefillParams {
            pin_sink: true,
            pin_recent: 1,
            recall_countdowns: vec![usize::MAX; n_layers],
            head_groups: 1,
        }
    }

    let stack = common::stack();
    let spec = stack.gpu.spec.clone();
    let (bs, w) = (spec.block_size, spec.n_kv_heads * spec.head_dim);
    let shared = prompt(4 * bs, 7); // block-aligned shared system prefix
    let n_req = 4usize;
    let reqs: Vec<RequestSpec> = (0..n_req)
        .map(|i| {
            let mut p = shared.clone();
            p.extend(prompt(bs + i + 1, 50 + i as u32)); // divergent tails
            RequestSpec::new(i as u64, p, 4)
        })
        .collect();

    // Cold baselines: no pool anywhere.
    let cold: Vec<_> = reqs
        .iter()
        .map(|r| {
            let mut st = PrefillState::begin(&spec, r, spec.k_blocks, 16).unwrap();
            while !st.advance(&stack.gpu).unwrap() {}
            st.finish(&stack.native, params(spec.n_layers)).unwrap()
        })
        .collect();

    // One warm run publishes the shared blocks (and is then dropped, so
    // the pool keeps the only hold on each)...
    let pool = std::sync::Arc::new(PrefixPool::new(64));
    {
        let mut st = PrefillState::begin(&spec, &reqs[0], spec.k_blocks, 16).unwrap();
        st.attach_pool(pool.clone());
        while !st.advance(&stack.gpu).unwrap() {}
    }
    assert!(pool.stats().published > 0, "warm run must publish");

    // ...then every importer runs concurrently.
    let hot: Vec<_> = std::thread::scope(|s| {
        let handles: Vec<_> = reqs
            .iter()
            .map(|r| {
                let (gpu, native, spec, pool) =
                    (&stack.gpu, &stack.native, &spec, pool.clone());
                s.spawn(move || {
                    let mut st = PrefillState::begin(spec, r, spec.k_blocks, 16).unwrap();
                    st.attach_pool(pool);
                    while !st.advance(gpu).unwrap() {}
                    st.finish(native, params(spec.n_layers)).unwrap()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("importer panicked")).collect()
    });
    assert!(
        pool.stats().hits >= n_req as u64,
        "every importer must hit the shared chunks: {:?}",
        pool.stats()
    );

    for (h, c) in hot.iter().zip(&cold) {
        let n = h.cache.len();
        assert_eq!(n, c.cache.len(), "req {}", h.id);
        for layer in 0..spec.n_layers {
            let a = h.cache.layer(layer);
            let b = c.cache.layer(layer);
            let (mut ka, mut va) = (vec![0.0f32; n * w], vec![0.0f32; n * w]);
            let (mut kb, mut vb) = (vec![0.0f32; n * w], vec![0.0f32; n * w]);
            a.copy_rows_into(0, n, &mut ka, &mut va);
            b.copy_rows_into(0, n, &mut kb, &mut vb);
            assert_eq!(ka, kb, "k bits, req {}, layer {layer}", h.id);
            assert_eq!(va, vb, "v bits, req {}, layer {layer}", h.id);
            assert_eq!(a.digests(), b.digests(), "digests, req {}, layer {layer}", h.id);
        }
    }

    // Refcounts return to baseline: pool entry (1) + our probe (1).
    drop(hot);
    let mut key = CHAIN_SEED;
    for chunk in shared.chunks(bs) {
        key = chain_hash(key, chunk);
        let layers = pool.probe(key).expect("published chunk still resident");
        for arc in &layers {
            assert_eq!(
                std::sync::Arc::strong_count(arc),
                2,
                "imported block leaked a refcount after its sequence dropped"
            );
        }
    }
}

/// begin_drain racing a submission burst from another thread: late
/// submissions reject (never hang), accepted ones complete, the join
/// finishes.
#[test]
fn drain_racing_submitters_strands_no_client() {
    let mut cfg = pool_cfg();
    cfg.server.replicas = 2;
    let pool = std::sync::Arc::new(EnginePool::start(cfg).expect("pool start"));

    let p2 = pool.clone();
    let submitter = std::thread::spawn(move || {
        let mut handles = Vec::new();
        for i in 0..16 {
            handles.push(p2.submit(Submission::new(prompt(12, 100 + i), 3)));
            if i == 4 {
                // Mid-burst yield widens the race window around drain.
                std::thread::yield_now();
            }
        }
        handles
    });
    pool.begin_drain();
    let handles = submitter.join().expect("submitter panicked");
    for h in &handles {
        match wait_terminal(h) {
            StreamEvent::Done(_) | StreamEvent::Rejected(_) => {}
            other => panic!("expected Done or Rejected, got {other:?}"),
        }
    }
    let p3 = pool.clone();
    must_finish_within("drain racing submitters", WAIT, move || {
        p3.shutdown().expect("clean join");
    });
}

/// Session-tier eviction under pressure with concurrent readers: four
/// threads each run a three-turn conversation under their own session
/// key against a tier whose DRAM budget (3 block-sets) holds exactly
/// one session's working set and whose session cap (3) is below the
/// thread count. Suspends, LRU demotions to the spill file, demand
/// page-ins, and session evictions therefore race continuously.
///
/// Outputs of later turns legitimately depend on whether the session
/// survived eviction (exact resume restores the suspended scheduler
/// state; a miss re-prefills the history and recomputes it), so the
/// contract pinned here is liveness and accounting, not full byte
/// parity: every turn reaches a `Done` terminal with the right token
/// count, first turns (always fresh prefills) are byte-identical to
/// quiet keyless runs, every probe is answered exactly once
/// (`resumed + misses == probes`), the forced demotions and evictions
/// actually happened, and the drained pool holds zero inflight budget.
#[test]
fn tier_churn_under_concurrent_sessions_answers_everyone() {
    const THREADS: u32 = 4;
    const TURNS: usize = 3;
    let mut cfg = pool_cfg();
    cfg.server.replicas = 2;
    cfg.scout.tier_dram_blocks = 3; // one session's working set
    cfg.scout.tier_sessions = 3; // < THREADS: evictions race resumes
    let pool = std::sync::Arc::new(EnginePool::start(cfg).expect("pool start"));

    // Quiet keyless references for the first turns: fresh prefills are
    // deterministic per-sequence regardless of batch composition, so
    // these bytes must survive the churn untouched.
    let refs: Vec<Vec<u32>> = (0..THREADS)
        .map(|t| {
            pool.submit(Submission::new(prompt(32, 200 + t), 4))
                .wait()
                .expect("reference run")
                .generated
        })
        .collect();

    let workers: Vec<_> = (0..THREADS)
        .map(|t| {
            let pool = pool.clone();
            let turn1 = refs[t as usize].clone();
            std::thread::spawn(move || {
                let sid = format!("churn-{t}");
                let mut hist = prompt(32, 200 + t);
                for turn in 0..TURNS {
                    let out = pool
                        .submit(Submission::new(hist.clone(), 4).with_session_id(sid.clone()))
                        .wait()
                        .unwrap_or_else(|e| {
                            panic!("session {sid} turn {turn} must complete: {e:?}")
                        });
                    assert_eq!(out.generated.len(), 4, "session {sid} turn {turn}");
                    if turn == 0 {
                        assert_eq!(
                            out.generated, turn1,
                            "session {sid}: fresh first turn diverged under churn"
                        );
                    }
                    hist.extend_from_slice(&out.generated);
                }
            })
        })
        .collect();
    for w in workers {
        w.join().expect("session thread panicked");
    }

    let stats = pool.stats();
    let tier = stats.get("tier").expect("tier section in stats").clone();
    let probes = (THREADS as usize) * TURNS;
    let suspended = tier.req_usize("suspended").unwrap();
    let resumed = tier.req_usize("resumed").unwrap();
    let misses = tier.req_usize("misses").unwrap();
    // Every keyed finish suspends; every keyed admission probes, and a
    // probe is answered exactly once — resume or honest miss, never
    // both, never silently neither.
    assert!(suspended >= probes, "{suspended} suspends for {probes} keyed finishes");
    assert_eq!(
        resumed + misses,
        probes,
        "probe conservation violated: resumed={resumed} misses={misses}"
    );
    assert!(misses >= THREADS as usize, "first turns probe unknown keys");
    // Four final sessions against a cap of 3 guarantee an LRU eviction,
    // and two co-resident sessions (6 block-sets against a budget of 3)
    // guarantee demotions to the spill file.
    assert!(tier.req_usize("evicted").unwrap() >= 1, "session cap must evict");
    assert!(tier.req_usize("spilled").unwrap() >= 3, "DRAM budget must demote");

    let p2 = pool.clone();
    must_finish_within("tier churn shutdown", WAIT, move || {
        p2.shutdown().expect("clean join");
    });
    let inflight =
        pool.stats().req_usize("inflight_tokens").expect("inflight_tokens in stats");
    assert_eq!(inflight, 0, "tier churn leaked budget");
}
