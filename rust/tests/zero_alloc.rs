//! Steady-state allocation regression for the decode hot path.
//!
//! The interpreter backend's row temporaries come from a size-classed
//! scratch arena (`util::arena`): the first steps of a workload populate
//! the classes, and every later decode step must check the same sizes
//! back out with zero fresh allocations. The arena's high-water counter
//! (`Runtime::scratch_allocations`) makes that a hard assertion — if a
//! row regresses to `vec![0.0; ..]`-per-step (or a lease size starts
//! varying per step), the counter moves and this test fails.
//!
//! Runs the full Scout scheduler (worker groups, staged recall, gathers,
//! merges) on a single-threaded interpreter so lease concurrency — and
//! therefore the counter — is deterministic.

use std::sync::Arc;

use scoutattention::config::{RecallPolicy, ScoutConfig};
use scoutattention::coordinator::{Batch, DecodeScheduler, RecallController, ScoutScheduler};
use scoutattention::engines::{GpuEngine, NativeEngine};
use scoutattention::model::spec::builtin_preset;
use scoutattention::model::Weights;
use scoutattention::runtime::Runtime;
use scoutattention::workload::{LengthMix, WorkloadGen};

#[test]
fn steady_state_decode_keeps_the_scratch_arena_flat() {
    let spec = builtin_preset("test-tiny").unwrap();
    let rt = Arc::new(Runtime::for_spec_with_threads(&spec, 1).unwrap());
    let weights = Weights::generate(&spec, 7, 1.0);
    let gpu = Arc::new(GpuEngine::new(rt.clone(), weights.clone()).unwrap());
    let native = Arc::new(NativeEngine::new(spec.clone(), weights));
    let cfg = ScoutConfig {
        recall: RecallPolicy::Fixed { interval: 2 },
        ..ScoutConfig::default()
    };
    let recall = RecallController::new(&cfg, spec.n_layers, None);
    let mut sched = ScoutScheduler::new(gpu, native, cfg, recall);

    let mut batch = Batch::new(spec.clone(), 2, 2);
    let mut gen =
        WorkloadGen::new(3, spec.vocab, LengthMix::Fixed(spec.block_size * 3 + 2), 64);
    for req in (&mut gen).take(2) {
        sched.admit(&mut batch, &req).expect("prefill");
    }

    // Warm: a few decode steps populate every scratch size class
    // (crossing at least one block boundary along the way).
    for _ in 0..3 {
        sched.step(&mut batch).expect("warmup step");
    }
    let warm = rt.scratch_allocations().expect("interpreter backend has an arena");
    assert!(warm > 0, "decode should have populated scratch classes");

    for _ in 0..5 {
        sched.step(&mut batch).expect("steady step");
    }
    assert_eq!(
        rt.scratch_allocations().unwrap(),
        warm,
        "steady-state decode must not allocate interpreter row scratch"
    );
}
