//! Property-based tests over coordinator invariants (hand-rolled
//! rng-driven sweeps — the offline crate universe has no proptest; each
//! property runs hundreds of random cases with a seeded generator so
//! failures are reproducible from the printed seed).

mod common;

use scoutattention::engines::Partial;
use scoutattention::kvcache::ResidentSet;
use scoutattention::sparse::select_topk;
use scoutattention::util::Rng64;

fn rand_partial(rng: &mut Rng64, hq: usize, d: usize) -> Partial {
    let mut p = Partial::empty(hq, d);
    let tokens = rng.range(1, 12);
    for _ in 0..tokens {
        let h = rng.range(0, hq - 1);
        let s = (rng.f32() - 0.5) * 8.0;
        let v: Vec<f32> = (0..d).map(|_| rng.f32() - 0.5).collect();
        p.update_token(h, s, &v);
    }
    p
}

#[test]
fn prop_merge_associative_and_commutative() {
    for case in 0..300 {
        let mut rng = Rng64::new(1000 + case);
        let (hq, d) = (rng.range(1, 4), rng.range(1, 8));
        let a = rand_partial(&mut rng, hq, d);
        let b = rand_partial(&mut rng, hq, d);
        let c = rand_partial(&mut rng, hq, d);
        // (a+b)+c == a+(b+c)
        let mut ab = a.clone();
        ab.merge(&b);
        let mut ab_c = ab.clone();
        ab_c.merge(&c);
        let mut bc = b.clone();
        bc.merge(&c);
        let mut a_bc = a.clone();
        a_bc.merge(&bc);
        common::assert_close(&ab_c.finalize(), &a_bc.finalize(), 1e-4, 1e-5, &format!("assoc case {case}"));
        // a+b == b+a
        let mut ba = b.clone();
        ba.merge(&a);
        common::assert_close(&ab.finalize(), &ba.finalize(), 1e-5, 1e-6, &format!("comm case {case}"));
    }
}

#[test]
fn prop_merge_identity_and_self_consistency() {
    for case in 0..200 {
        let mut rng = Rng64::new(2000 + case);
        let (hq, d) = (rng.range(1, 4), rng.range(1, 8));
        let a = rand_partial(&mut rng, hq, d);
        let mut with_empty = a.clone();
        with_empty.merge(&Partial::empty(hq, d));
        common::assert_close(&with_empty.finalize(), &a.finalize(), 1e-6, 1e-7, "identity");
        // merging a with itself doubles l but leaves the output unchanged
        let mut doubled = a.clone();
        doubled.merge(&a);
        common::assert_close(&doubled.finalize(), &a.finalize(), 1e-5, 1e-6, "self-merge output");
        for (l2, l1) in doubled.l.iter().zip(&a.l) {
            assert!((l2 - 2.0 * l1).abs() <= 1e-4 * l1.abs() + 1e-6, "self-merge l");
        }
    }
}

#[test]
fn prop_topk_selection_invariants() {
    for case in 0..400 {
        let mut rng = Rng64::new(3000 + case);
        let n = rng.range(1, 40);
        let k = rng.range(1, 20);
        let scores: Vec<f32> = (0..n)
            .map(|_| if rng.bool(0.15) { f32::NEG_INFINITY } else { (rng.f32() - 0.5) * 10.0 })
            .collect();
        let n_pins = rng.range(0, 3.min(n));
        let pins: Vec<usize> = (0..n_pins).map(|_| rng.range(0, n - 1)).collect();
        let sel = select_topk(&scores, k, &pins);
        // size bound
        assert!(sel.blocks.len() <= k);
        // no duplicates
        let mut sorted = sel.blocks.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), sel.blocks.len(), "dupes in {:?}", sel.blocks);
        // only finite-score blocks
        assert!(sel.blocks.iter().all(|&b| scores[b].is_finite()));
        // pins (with finite scores) come first, then scores descend
        let finite_pins: Vec<usize> =
            pins.iter().copied().filter(|&p| scores[p].is_finite()).collect();
        for (i, &p) in finite_pins.iter().take(k).enumerate() {
            if !finite_pins[..i].contains(&p) {
                assert!(sel.blocks.contains(&p), "pin {p} missing (case {case})");
            }
        }
        // unpinned tail is sorted by score descending
        let tail: Vec<usize> = sel
            .blocks
            .iter()
            .copied()
            .filter(|b| !finite_pins.contains(b))
            .collect();
        for w in tail.windows(2) {
            assert!(scores[w[0]] >= scores[w[1]], "tail not sorted (case {case})");
        }
        // optimality: any unselected finite block scores <= the minimum
        // unpinned selected block
        if let Some(&min_sel) = tail.last() {
            for b in 0..n {
                if scores[b].is_finite() && !sel.blocks.contains(&b) && sel.blocks.len() == k {
                    assert!(
                        scores[b] <= scores[min_sel] + 1e-6,
                        "missed better block {b} (case {case})"
                    );
                }
            }
        }
    }
}

#[test]
fn prop_resident_set_refresh_and_partition() {
    for case in 0..300 {
        let mut rng = Rng64::new(4000 + case);
        let nb = rng.range(2, 48);
        let cap = rng.range(1, nb);
        let mut rs = ResidentSet::new(nb, cap);
        let mut prev: Vec<usize> = Vec::new();
        for _round in 0..6 {
            let want = rng.range(0, nb);
            let mut ranked: Vec<usize> = Vec::new();
            for _ in 0..want {
                let b = rng.range(0, nb - 1);
                if !ranked.contains(&b) {
                    ranked.push(b);
                }
            }
            let added = rs.refresh(&ranked);
            // capacity respected
            assert!(rs.len() <= cap);
            // the kept set is exactly the first cap of ranked
            let kept: Vec<usize> = ranked.iter().copied().take(cap).collect();
            for &b in &kept {
                assert!(rs.contains(b));
            }
            // added = kept \ prev
            for &b in &added {
                assert!(kept.contains(&b) && !prev.contains(&b), "case {case}");
            }
            // partition covers the selected set exactly once
            let selected: Vec<usize> = (0..nb).filter(|_| rng.bool(0.3)).collect();
            let (gpu, cpu) = rs.partition(&selected);
            assert_eq!(gpu.len() + cpu.len(), selected.len());
            for &g in &gpu {
                assert!(rs.contains(g));
            }
            for &c in &cpu {
                assert!(!rs.contains(c));
            }
            prev = kept;
        }
    }
}

#[test]
fn prop_json_roundtrip_fuzz() {
    use scoutattention::util::Json;
    fn rand_json(rng: &mut Rng64, depth: usize) -> Json {
        match if depth == 0 { rng.range(0, 2) } else { rng.range(0, 5) } {
            0 => Json::Num((rng.f64() - 0.5) * 1e6),
            1 => Json::str(format!("s{}\n\"x{}", rng.next_u64() % 1000, rng.range(0, 9))),
            2 => Json::Bool(rng.bool(0.5)),
            3 => Json::Arr((0..rng.range(0, 4)).map(|_| rand_json(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.range(0, 4))
                    .map(|i| (format!("k{i}"), rand_json(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    for case in 0..300 {
        let mut rng = Rng64::new(5000 + case);
        let j = rand_json(&mut rng, 3);
        let text = j.to_string();
        let back = Json::parse(&text).unwrap_or_else(|e| panic!("case {case}: {e}\n{text}"));
        match (&j, &back) {
            (Json::Num(a), Json::Num(b)) => assert!((a - b).abs() <= 1e-9 * a.abs().max(1.0)),
            _ => assert_eq!(j, back, "case {case}"),
        }
    }
}

#[test]
fn prop_histogram_quantiles_bounded() {
    use scoutattention::metrics::Histogram;
    for case in 0..100 {
        let mut rng = Rng64::new(6000 + case);
        let mut h = Histogram::new();
        let n = rng.range(1, 500);
        let mut max = 0.0f64;
        for _ in 0..n {
            let v = rng.f64() * 1e5;
            max = max.max(v);
            h.record(v);
        }
        assert_eq!(h.count(), n as u64);
        for q in [0.0, 0.5, 0.9, 0.99, 1.0] {
            let x = h.quantile(q);
            assert!(x >= h.min() - 1e-9 && x <= h.max() + 1e-9, "q{q}={x} case {case}");
        }
        assert!(h.quantile(0.5) <= h.quantile(0.99) + 1e-9);
    }
}
