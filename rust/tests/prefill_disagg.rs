//! Prefill/decode disaggregation integration suite.
//!
//! Pins the three contracts of the staged prefill plane:
//!
//! 1. **Chunked prefill is exact**: a `PrefillState` advanced in chunks
//!    of any size leaves the KV cache, digests, and final hidden state
//!    *bitwise identical* to the fused whole-prompt prefill artifact,
//!    and end-to-end generation is byte-identical across chunk sizes.
//! 2. **KV handoff is lossless**: `export_seq`/`import_seq` roundtrips
//!    a prefilled sequence without changing a byte, and a role-split
//!    pool (prefill replica + decode replicas, KV migrating between
//!    stacks) produces exactly the single-replica outputs.
//! 3. **Cancellation during prefill** frees the request with the
//!    distinct `Cancelled` terminal.

mod common;

use std::sync::Arc;
use std::time::Duration;

use scoutattention::config::{Method, ReplicaRole, RunConfig};
use scoutattention::coordinator::{PrefillParams, PrefillState, RequestSpec};
use scoutattention::harness;
use scoutattention::kvcache::{LayerView, PrefixPool, ShardedKvCache};
use scoutattention::serve::{EnginePool, StreamEvent, StreamHandle, Submission};
use scoutattention::tensor::Tensor;

const WAIT: Duration = Duration::from_secs(120);

/// Deterministic prompt in test-tiny vocab (256), avoiding pad token 0.
fn prompt(len: usize, salt: u32) -> Vec<u32> {
    (0..len as u32).map(|i| 1 + (i * 29 + salt * 11) % 255).collect()
}

/// First `n` K/V rows of a layer, walked block by block (blocks are no
/// longer one contiguous slab under refcounted storage).
fn kv_prefix(view: &LayerView<'_>, n: usize, w: usize) -> (Vec<f32>, Vec<f32>) {
    let (mut k, mut v) = (vec![0.0f32; n * w], vec![0.0f32; n * w]);
    view.copy_rows_into(0, n, &mut k, &mut v);
    (k, v)
}

#[test]
fn chunked_prefill_is_bitwise_identical_to_fused() {
    let stack = common::stack();
    let spec = stack.gpu.spec.clone();
    let n = spec.max_seq / 2 + 3; // crosses several block boundaries
    let req = RequestSpec::new(7, prompt(n, 1), 4);

    // Fused reference: the whole-prompt artifact, loaded the seed's way.
    let mut x_seq = Tensor::zeros(&[spec.max_seq, spec.d_model]);
    for (t, &tok) in req.prompt.iter().take(n).enumerate() {
        x_seq.rows_mut(t, 1).copy_from_slice(stack.gpu.weights.embed_token(tok));
    }
    let (k, v, h_last, _logits) = stack.gpu.prefill(&x_seq, n).unwrap();
    let reference = ShardedKvCache::new(&spec);
    for layer in 0..spec.n_layers {
        reference.load_prefill_layer(layer, k.rows(layer, 1), v.rows(layer, 1), n);
    }
    reference.finish_prefill(n);
    let mut residents: Vec<Vec<Vec<usize>>> = Vec::new();
    for chunk in [1, 3, 64, usize::MAX] {
        let mut st = PrefillState::begin(&spec, &req, spec.k_blocks, chunk).unwrap();
        let mut calls = 1;
        while !st.advance(&stack.gpu).unwrap() {
            calls += 1;
        }
        if chunk >= n {
            assert_eq!(calls, 1, "one advance() call must finish a whole-prompt chunk");
        } else {
            assert_eq!(calls, n.div_ceil(chunk), "chunk accounting (chunk={chunk})");
        }
        // The K/V bit-parity below pins each layer's *input*; the final
        // hidden state (last layer's epilogue output, which seeds
        // resident-set selection) must be pinned explicitly too.
        assert_eq!(st.h_last(), h_last.data(), "h_last bits (chunk={chunk})");
        let seq = st
            .finish(
                &stack.native,
                PrefillParams {
                    pin_sink: true,
                    pin_recent: 1,
                    recall_countdowns: vec![usize::MAX; spec.n_layers],
                    head_groups: 1,
                },
            )
            .unwrap();
        assert_eq!(seq.cache.len(), n, "chunk={chunk}");
        let w = spec.n_kv_heads * spec.head_dim;
        for layer in 0..spec.n_layers {
            let a = seq.cache.layer(layer);
            let b = reference.layer(layer);
            let (ka, va) = kv_prefix(&a, n, w);
            let (kb, vb) = kv_prefix(&b, n, w);
            assert_eq!(ka, kb, "k bits, layer {layer} chunk {chunk}");
            assert_eq!(va, vb, "v bits, layer {layer} chunk {chunk}");
            assert_eq!(a.digests(), b.digests(), "digests, layer {layer} chunk {chunk}");
        }
        // Resident-set initialization (digest scores against the final
        // hidden state) must be chunk-invariant too.
        let res: Vec<Vec<usize>> =
            (0..spec.n_layers).map(|l| seq.resident[l].iter().collect()).collect();
        assert!(res.iter().all(|r| !r.is_empty()), "resident sets initialized");
        residents.push(res);
    }
    for (i, r) in residents.iter().enumerate().skip(1) {
        assert_eq!(r, &residents[0], "resident sets diverge across chunk sizes (arm {i})");
    }
}

#[test]
fn generation_is_byte_identical_across_chunk_sizes() {
    let base_cfg = RunConfig::for_preset(common::PRESET);
    let stack = harness::Stack::load(&base_cfg).unwrap();
    let spec = stack.gpu.spec.clone();
    let reqs = |salt: u32| {
        vec![
            RequestSpec::new(0, prompt(spec.max_seq / 2, salt), 6),
            RequestSpec::new(1, prompt(17, salt + 1), 6),
        ]
    };
    // Inline whole-prompt arm (chunk >= prompt) is the pre-refactor
    // behavior; every chunked arm must match it byte for byte.
    let mut reference = None;
    for chunk in [usize::MAX, 512, 16, 5] {
        let mut cfg = base_cfg.clone();
        cfg.scout.prefill_chunk = chunk;
        let stack = harness::Stack::load(&cfg).unwrap();
        let run = harness::run_method(&stack, Method::Scout, reqs(3), 1000, None).unwrap();
        let toks: Vec<Vec<u32>> = run.outputs.iter().map(|o| o.generated.clone()).collect();
        match &reference {
            None => reference = Some(toks),
            Some(want) => {
                assert_eq!(&toks, want, "chunk={chunk} diverged from inline prefill")
            }
        }
    }
}

#[test]
fn prefix_cache_hit_is_bitwise_identical_to_cold_prefill() {
    let stack = common::stack();
    let spec = stack.gpu.spec.clone();
    let n = spec.max_seq / 2 + 3; // several full blocks + a partial tail
    let req = RequestSpec::new(1, prompt(n, 9), 4);
    let params = || PrefillParams {
        pin_sink: true,
        pin_recent: 1,
        recall_countdowns: vec![usize::MAX; spec.n_layers],
        head_groups: 1,
    };

    // Cold reference: no pool attached at all.
    let mut cold = PrefillState::begin(&spec, &req, spec.k_blocks, 16).unwrap();
    while !cold.advance(&stack.gpu).unwrap() {}
    let cold_h = cold.h_last().to_vec();
    let cold_seq = cold.finish(&stack.native, params()).unwrap();

    // Warm-up run publishes its full chunks into the pool...
    let pool = Arc::new(PrefixPool::new(64));
    let mut warm = PrefillState::begin(&spec, &req, spec.k_blocks, 16).unwrap();
    warm.attach_pool(pool.clone());
    while !warm.advance(&stack.gpu).unwrap() {}
    let after_warm = pool.stats();
    assert!(after_warm.published > 0, "warm run must publish full chunks");
    assert_eq!(after_warm.hits, 0, "nothing to hit on a cold pool");

    // ...so the second run imports them instead of computing. Everything
    // downstream of the import must be bitwise identical to cold:
    // generation determinism is the tentpole contract.
    let mut hit = PrefillState::begin(&spec, &req, spec.k_blocks, 16).unwrap();
    hit.attach_pool(pool.clone());
    while !hit.advance(&stack.gpu).unwrap() {}
    assert!(pool.stats().hits > 0, "second run must hit the pool");
    assert_eq!(hit.h_last(), &cold_h[..], "h_last bits after imported prefix");
    let hit_seq = hit.finish(&stack.native, params()).unwrap();

    let w = spec.n_kv_heads * spec.head_dim;
    for layer in 0..spec.n_layers {
        let a = hit_seq.cache.layer(layer);
        let b = cold_seq.cache.layer(layer);
        let (ka, va) = kv_prefix(&a, n, w);
        let (kb, vb) = kv_prefix(&b, n, w);
        assert_eq!(ka, kb, "k bits, layer {layer}");
        assert_eq!(va, vb, "v bits, layer {layer}");
        assert_eq!(a.digests(), b.digests(), "digests, layer {layer}");
    }
    // Resident-set selection consumes digests + h_last only, so it must
    // be hit-invariant too.
    for layer in 0..spec.n_layers {
        let a: Vec<usize> = hit_seq.resident[layer].iter().collect();
        let b: Vec<usize> = cold_seq.resident[layer].iter().collect();
        assert_eq!(a, b, "resident set diverged on layer {layer}");
    }
}

#[test]
fn prefix_cache_pool_serves_identical_bytes_and_counts_hits() {
    // End-to-end through the serving plane: same shared system prompt
    // submitted twice — the second request must generate byte-identical
    // output while the pool records hits, and `{"stats":true}` surfaces
    // the counters.
    let mut cfg = RunConfig::for_preset(common::PRESET);
    cfg.server.replicas = 1;
    cfg.scout.prefill_chunk = 16;
    cfg.scout.prefix_cache_blocks = 64;
    let pool = EnginePool::start(cfg.clone()).expect("pool start");
    let spec = pool.spec().clone();
    let shared = prompt(spec.max_seq / 2, 4);

    let first = pool.submit(Submission::new(shared.clone(), 5)).wait().unwrap();
    let second = pool.submit(Submission::new(shared.clone(), 5)).wait().unwrap();
    assert_eq!(first.generated, second.generated, "reuse changed generation bytes");

    let stats = pool.stats();
    let prefix = stats.get("prefix").expect("prefix counters in stats");
    assert!(prefix.req_usize("hits").unwrap() > 0, "second request must hit");
    assert!(prefix.req_usize("published").unwrap() > 0);
    assert!(prefix.req_usize("entries").unwrap() > 0);
    pool.shutdown().expect("shutdown");

    // And the no-cache path generates the same bytes (cfg default 0).
    let mut cold_cfg = RunConfig::for_preset(common::PRESET);
    cold_cfg.server.replicas = 1;
    cold_cfg.scout.prefill_chunk = 16;
    let cold_pool = EnginePool::start(cold_cfg).expect("pool start");
    let cold = cold_pool.submit(Submission::new(shared, 5)).wait().unwrap();
    assert_eq!(cold.generated, first.generated, "cache on/off diverged");
    cold_pool.shutdown().expect("shutdown");
}

#[test]
fn role_split_pool_matches_single_shot_outputs() {
    // 1 prefill-only + 2 decode-only replicas: every admission prefills
    // on replica 0 and migrates (export/import) to a decode replica.
    let mut cfg = RunConfig::for_preset(common::PRESET);
    cfg.server.replicas = 3;
    cfg.server.roles =
        vec![ReplicaRole::Prefill, ReplicaRole::Decode, ReplicaRole::Decode];
    cfg.scout.prefill_chunk = 16;
    let pool = EnginePool::start(cfg.clone()).expect("pool start");

    let prompts: Vec<Vec<u32>> = (0..5).map(|i| prompt(24 + 16 * (i % 3), i as u32)).collect();
    let new_tokens = 5usize;
    let handles: Vec<StreamHandle> = prompts
        .iter()
        .enumerate()
        .map(|(i, p)| {
            let mut sub = Submission::new(p.clone(), new_tokens);
            if i % 2 == 0 {
                sub = sub.streaming();
            }
            pool.submit(sub)
        })
        .collect();
    let mut outputs: Vec<_> = handles
        .into_iter()
        .map(|h| {
            // wait() also validates streamed tokens == final output
            h.wait().expect("request completed through the handoff plane")
        })
        .collect();
    outputs.sort_by_key(|o| o.id);

    // Telemetry must show the disaggregated flow actually happened.
    let stats = pool.stats();
    assert_eq!(stats.req_usize("handoffs").unwrap(), prompts.len(), "every request migrated");
    assert!(stats.req_usize("handoff_bytes").unwrap() > 0);
    let reps = stats.get("replicas").unwrap().as_arr().unwrap();
    assert_eq!(reps[0].req_usize("handoffs_out").unwrap(), prompts.len());
    assert_eq!(reps[0].req_usize("steps").unwrap(), 0, "prefill replica never decodes");
    assert!(reps[0].req_usize("prefill_chunks").unwrap() >= prompts.len());
    assert_eq!(
        reps[1].req_usize("handoffs_in").unwrap() + reps[2].req_usize("handoffs_in").unwrap(),
        prompts.len()
    );
    pool.shutdown().expect("shutdown");

    // Byte parity with the single-shot path (one mixed replica, no
    // handoffs, same numerics plane).
    let single = harness::Stack::load(&RunConfig::for_preset(common::PRESET)).unwrap();
    for (i, p) in prompts.iter().enumerate() {
        let reqs = vec![RequestSpec::new(0, p.clone(), new_tokens)];
        let reference = harness::run_method(&single, Method::Scout, reqs, 1000, None).unwrap();
        assert_eq!(
            outputs[i].generated, reference.outputs[0].generated,
            "request {i}: disaggregated decode must match the single-shot path"
        );
    }
}

#[test]
fn session_affinity_with_roles_never_lands_on_prefill_only_replica() {
    let mut cfg = RunConfig::for_preset(common::PRESET);
    cfg.server.replicas = 3;
    cfg.server.roles =
        vec![ReplicaRole::Prefill, ReplicaRole::Decode, ReplicaRole::Decode];
    cfg.server.policy = "session_affinity".parse().unwrap();
    let pool = EnginePool::start(cfg).expect("pool start");
    // Whatever each session hashes to, every request must complete: the
    // router falls back off role-masked replicas instead of hanging.
    let handles: Vec<StreamHandle> = (0..6)
        .map(|i| {
            pool.submit(
                Submission::new(prompt(16, i), 3).with_session(format!("sess-{i}")),
            )
        })
        .collect();
    for h in handles {
        let out = h.wait().expect("affine request completed");
        assert_eq!(out.generated.len(), 3);
    }
    pool.shutdown().expect("shutdown");
}

#[test]
fn cancellation_during_chunked_prefill_is_distinct_and_frees_budget() {
    let mut cfg = RunConfig::for_preset(common::PRESET);
    cfg.server.replicas = 1;
    cfg.scout.prefill_chunk = 1; // many chunks: a wide cancel window
    let pool = EnginePool::start(cfg).expect("pool start");
    let spec = pool.spec().clone();

    let h = pool.submit(Submission::new(prompt(spec.max_seq / 2, 1), 8).streaming());
    pool.cancel(&h);
    let terminal = loop {
        match h.recv_timeout(WAIT) {
            Some(StreamEvent::Token { .. }) => continue,
            Some(ev) => break ev,
            None => panic!("stream closed without a terminal event"),
        }
    };
    match terminal {
        // The cancel may land during prefill (no tokens ever published)
        // or after completion if the tiny prompt raced through — both
        // must answer the client; mid-prefill it must be `Cancelled`.
        StreamEvent::Cancelled { id } => assert_eq!(id, h.id),
        StreamEvent::Done(_) => {}
        other => panic!("expected Cancelled or Done, got {other:?}"),
    }
    // Either way the reservation is released: a full-budget submission
    // still fits afterwards.
    let h2 = pool.submit(Submission::new(prompt(16, 2), 2));
    assert_eq!(h2.wait().expect("pool still serves").generated.len(), 2);
    pool.shutdown().expect("shutdown");
}
