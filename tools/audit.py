#!/usr/bin/env python3
"""Reference mirror of `cargo xtask audit` (see xtask/src/).

The xtask crate is the canonical implementation — CI runs it. This
mirror exists so the audit can also run in environments without a Rust
toolchain (the offline authoring container, pre-commit hooks on minimal
machines). Rule semantics are kept line-for-line equivalent with
xtask/src/{scan,audit}.rs; `--self-test` runs the same fixture table.

Usage: tools/audit.py [--root DIR] [--self-test]
Exit: 0 clean, 1 violations, 2 usage/IO error.
"""

import os
import sys

ORDERING_VARIANTS = {"Relaxed", "Acquire", "Release", "AcqRel", "SeqCst"}
BLOCKING_CALLS = [
    ".send(", ".try_send(", ".execute(", "export_seq(", "import_seq(",
    ".probe(", ".publish(", ".spill(", ".page_in(",
]
GUARD_CALLS = [".lock()", ".read()", ".write()", ".layer("]
POISON_IDIOMS = (".lock()", ".read()", ".write()", ".into_inner()")


def is_ident(ch):
    return ch.isalnum() or ch == "_"


class Source:
    """Masked view of a Rust source file (strings/comments blanked)."""

    def __init__(self, path, text):
        self.path = path
        self.text = text
        self._mask()
        self._depth_and_lines()
        self._find_test_spans()

    # -- pass 1: masking ------------------------------------------------
    def _mask(self):
        t = self.text
        n = len(t)
        masked = list(t)
        comments = []  # (line, pos, text, trailing)
        line = 1
        line_has_code = False
        i = 0
        while i < n:
            c = t[i]
            if c == "\n":
                line += 1
                line_has_code = False
                i += 1
            elif c == "/" and t[i + 1 : i + 2] == "/":
                start = i
                while i < n and t[i] != "\n":
                    masked[i] = " "
                    i += 1
                comments.append((line, start, t[start:i], line_has_code))
            elif c == "/" and t[i + 1 : i + 2] == "*":
                start, start_line, trailing = i, line, line_has_code
                nest = 1
                masked[i] = masked[i + 1] = " "
                i += 2
                while i < n and nest > 0:
                    if t[i : i + 2] == "/*":
                        nest += 1
                        masked[i] = masked[i + 1] = " "
                        i += 2
                    elif t[i : i + 2] == "*/":
                        nest -= 1
                        masked[i] = masked[i + 1] = " "
                        i += 2
                    else:
                        if t[i] == "\n":
                            line += 1
                        else:
                            masked[i] = " "
                        i += 1
                comments.append((start_line, start, t[start:i], trailing))
            elif c == '"':
                line_has_code = True
                masked[i] = " "
                i += 1
                while i < n:
                    if t[i] == "\\" and i + 1 < n:
                        masked[i] = " "
                        if t[i + 1] != "\n":
                            masked[i + 1] = " "
                        else:
                            line += 1
                        i += 2
                    elif t[i] == '"':
                        masked[i] = " "
                        i += 1
                        break
                    else:
                        if t[i] == "\n":
                            line += 1
                        else:
                            masked[i] = " "
                        i += 1
            elif c == "r" and self._raw_hashes(t, i) is not None:
                line_has_code = True
                hashes = self._raw_hashes(t, i)
                open_len = 1 + hashes + 1
                for k in range(open_len):
                    masked[i + k] = " "
                i += open_len
                close = '"' + "#" * hashes
                while i < n:
                    if t[i : i + len(close)] == close:
                        for k in range(len(close)):
                            masked[i + k] = " "
                        i += len(close)
                        break
                    if t[i] == "\n":
                        line += 1
                    else:
                        masked[i] = " "
                    i += 1
            elif c == "'":
                line_has_code = True
                if t[i + 1 : i + 2] == "\\":
                    masked[i] = " "
                    i += 1
                    while i < n and t[i] != "'":
                        masked[i] = " "
                        i += 1
                    if i < n:
                        masked[i] = " "
                        i += 1
                elif t[i + 2 : i + 3] == "'" and t[i + 1 : i + 2] != "'":
                    masked[i] = masked[i + 1] = masked[i + 2] = " "
                    i += 3
                else:
                    i += 1  # lifetime
            else:
                if c not in " \t\r":
                    line_has_code = True
                i += 1
        self.masked = "".join(masked)
        self.comments = comments

    @staticmethod
    def _raw_hashes(t, i):
        if i > 0 and is_ident(t[i - 1]):
            return None
        j = i + 1
        hashes = 0
        while j < len(t) and t[j] == "#":
            hashes += 1
            j += 1
        return hashes if j < len(t) and t[j] == '"' else None

    # -- pass 2: depth + line starts ------------------------------------
    def _depth_and_lines(self):
        self.line_starts = [0]
        depth = []
        cur = 0
        for j, b in enumerate(self.masked):
            depth.append(cur)
            if b == "\n":
                self.line_starts.append(j + 1)
            elif b == "{":
                cur += 1
            elif b == "}":
                cur = max(0, cur - 1)
        depth.append(cur)
        self.depth = depth

    def line_of(self, pos):
        import bisect

        return bisect.bisect_right(self.line_starts, pos)

    def masked_line(self, line):
        start = self.line_starts[line - 1]
        end = (
            self.line_starts[line] - 1
            if line < len(self.line_starts)
            else len(self.masked)
        )
        return self.masked[start : max(end, start)]

    def num_lines(self):
        return len(self.line_starts)

    def in_test(self, pos):
        return any(s <= pos < e for s, e in self.test_spans)

    def block_end(self, pos):
        d = self.depth[pos]
        for j in range(pos + 1, len(self.depth)):
            if self.depth[j] < d:
                return j
        return len(self.text)

    def annotated(self, site_line, pred):
        if any(pred(c[2]) for c in self.comments if c[0] == site_line):
            return True
        l = site_line
        while l > 1:
            l -= 1
            code = self.masked_line(l).strip()
            line_comments = [c for c in self.comments if c[0] == l]
            if not code and line_comments:
                if any(pred(c[2]) for c in line_comments):
                    return True
                continue
            if code.startswith("#[") or code.startswith("#!["):
                continue
            return False
        return False

    def _find_test_spans(self):
        spans = []
        needle = "#[cfg(test)]"
        frm = 0
        while True:
            attr = self.masked.find(needle, frm)
            if attr < 0:
                break
            frm = attr + len(needle)
            brace = self.masked.find("{", attr + len(needle))
            if brace < 0:
                continue
            between = self.masked[attr + len(needle) : brace]
            if "mod" not in between.split():
                continue
            d = self.depth[brace]
            end = len(self.text)
            for j in range(brace + 1, len(self.depth)):
                if self.depth[j] == d:
                    end = j
                    break
            spans.append((attr, end))
            frm = end
        self.test_spans = spans


def word_positions(hay, word):
    out = []
    frm = 0
    while True:
        pos = hay.find(word, frm)
        if pos < 0:
            return out
        frm = pos + len(word)
        before_ok = pos == 0 or not is_ident(hay[pos - 1])
        after = pos + len(word)
        after_ok = after >= len(hay) or not is_ident(hay[after])
        if before_ok and after_ok:
            out.append(pos)


def in_guarded_dirs(path):
    return any(d in path for d in ("coordinator/", "kvcache/", "serve/"))


def in_hot_path(path):
    return in_guarded_dirs(path) or path.endswith(
        ("tensor.rs", "util/simd.rs", "util/arena.rs", "util/par.rs")
    )


def check_unsafe(src, out):
    for pos in word_positions(src.masked, "unsafe"):
        if src.in_test(pos):
            continue
        line = src.line_of(pos)
        if not src.annotated(line, lambda c: "SAFETY:" in c or "# Safety" in c):
            out.append((src.path, line, "unsafe-safety", "`unsafe` without `// SAFETY:`"))


def check_ordering(src, out):
    intervals = [
        (c[1], src.block_end(c[1]), c[0])
        for c in src.comments
        if "ordering:" in c[2].lower()
    ]
    frm = 0
    while True:
        pos = src.masked.find("Ordering::", frm)
        if pos < 0:
            return
        frm = pos + len("Ordering::")
        rest = src.masked[pos + len("Ordering::") :]
        variant = ""
        for ch in rest:
            if ch.isalnum():
                variant += ch
            else:
                break
        if variant not in ORDERING_VARIANTS or src.in_test(pos):
            continue
        line = src.line_of(pos)
        covered = any(
            cline == line or (start < pos < end) for start, end, cline in intervals
        )
        if not covered:
            out.append(
                (src.path, line, "ordering-note",
                 f"Ordering::{variant} without `// ordering:` justification")
            )


def guard_binding(content):
    lets = word_positions(content, "let")
    if not lets:
        return None
    let_pos = lets[0]
    rest = content[let_pos + 3 :].lstrip()
    if rest.startswith("mut "):
        rest = rest[4:].lstrip()
    name = ""
    for ch in rest:
        if is_ident(ch):
            name += ch
        else:
            break
    if not name:
        return None
    after_name = rest[len(name) :].lstrip()
    if not after_name.startswith("="):
        return None
    rhs = after_name[1:]
    call_positions = [rhs.find(c) for c in GUARD_CALLS if rhs.find(c) >= 0]
    if not call_positions:
        return None
    prefix = rhs[: min(call_positions)]
    for kw in ("match", "if", "loop", "while"):
        if word_positions(prefix, kw):
            return None
    return name, let_pos


def check_lock_across(src, out):
    guards = []  # (name, depth, line)
    for line in range(1, src.num_lines() + 1):
        start = src.line_starts[line - 1]
        if src.in_test(start):
            continue
        content = src.masked_line(line)

        for dpos in word_positions(content, "drop"):
            rest = content[dpos + 4 :]
            if rest.startswith("("):
                name = ""
                for ch in rest[1:]:
                    if is_ident(ch):
                        name += ch
                    else:
                        break
                guards = [g for g in guards if g[0] != name]

        for call in BLOCKING_CALLS:
            cfrm = 0
            while True:
                cpos = content.find(call, cfrm)
                if cpos < 0:
                    break
                cfrm = cpos + len(call)
                if not call.startswith(".") and cpos > 0 and is_ident(content[cpos - 1]):
                    continue
                cur_depth = src.depth[start + cpos]
                for g in guards:
                    if cur_depth >= g[1]:
                        if not src.annotated(
                            line, lambda c: "audit: allow(lock_across" in c
                        ):
                            out.append(
                                (src.path, line, "lock-across",
                                 f"blocking call `{call.strip('.(')}` while guard "
                                 f"`{g[0]}` (line {g[2]}) is live")
                            )

        gb = guard_binding(content)
        if gb:
            name, let_pos = gb
            guards = [g for g in guards if g[0] != name]
            guards.append((name, src.depth[start + let_pos], line))

        eol = (
            src.line_starts[line] if line < len(src.line_starts) else len(src.masked)
        )
        end_depth = src.depth[min(eol, len(src.depth) - 1)]
        guards = [g for g in guards if g[1] <= end_depth]


def check_unwrap(src, out):
    for needle in (".unwrap()", ".expect("):
        frm = 0
        while True:
            pos = src.masked.find(needle, frm)
            if pos < 0:
                break
            frm = pos + len(needle)
            if src.in_test(pos):
                continue
            before = src.masked[:pos].rstrip()
            if before.endswith(POISON_IDIOMS):
                continue
            line = src.line_of(pos)
            if not src.annotated(
                line,
                lambda c: "audit: allow(unwrap" in c or "audit: allow(expect" in c,
            ):
                out.append(
                    (src.path, line, "unwrap-hot",
                     f"`{needle.strip('.(')}` in a hot-path module")
                )


def check_unwind_safety(src, out):
    for word in ("catch_unwind", "AssertUnwindSafe"):
        for pos in word_positions(src.masked, word):
            if src.in_test(pos):
                continue
            line = src.line_of(pos)
            if not src.annotated(line, lambda c: "unwind-safety:" in c):
                out.append(
                    (src.path, line, "unwind-safety",
                     f"`{word}` without an `// unwind-safety:` comment arguing why "
                     "state observable after the unwind is consistent")
                )


def check_lib_attrs(src, out):
    if src.path.endswith("rust/src/lib.rs") and (
        "#![deny(unsafe_op_in_unsafe_fn)]" not in src.masked
    ):
        out.append((src.path, 1, "deny-attr",
                    "crate root must carry #![deny(unsafe_op_in_unsafe_fn)]"))


def audit_source(src):
    out = []
    check_unsafe(src, out)
    check_unwind_safety(src, out)
    check_ordering(src, out)
    if in_guarded_dirs(src.path):
        check_lock_across(src, out)
    if in_hot_path(src.path):
        check_unwrap(src, out)
    out.sort(key=lambda v: v[1])
    return out


# -- self-test fixtures (mirrors xtask/src/selftest.rs) -----------------

FIXTURES = [
    ("bare_unsafe_block_fails", "rust/src/util/x.rs",
     "pub fn f(p: *const u8) -> u8 {\n    unsafe { *p }\n}\n", ["unsafe-safety"]),
    ("commented_unsafe_block_passes", "rust/src/util/x.rs",
     "pub fn f(p: *const u8) -> u8 {\n    // SAFETY: caller contract\n    unsafe { *p }\n}\n", []),
    ("safety_above_target_feature_passes", "rust/src/util/x.rs",
     "// SAFETY: caller checks avx2\n#[target_feature(enable = \"avx2\")]\nunsafe fn f() {}\n", []),
    ("unannotated_relaxed_fails", "rust/src/util/x.rs",
     "use std::sync::atomic::{AtomicUsize, Ordering};\npub fn f(a: &AtomicUsize) -> usize {\n    a.load(Ordering::Relaxed)\n}\n", ["ordering-note"]),
    ("trailing_ordering_comment_passes", "rust/src/util/x.rs",
     "use std::sync::atomic::{AtomicUsize, Ordering};\npub fn f(a: &AtomicUsize) -> usize {\n    a.load(Ordering::Relaxed) // ordering: pure counter\n}\n", []),
    ("block_scoped_ordering_comment_covers_cluster", "rust/src/util/x.rs",
     "use std::sync::atomic::{AtomicUsize, Ordering};\npub fn f(a: &AtomicUsize) -> usize {\n    // ordering: both loads are monotonic gauges\n    let x = a.load(Ordering::Relaxed);\n    x + a.load(Ordering::Relaxed)\n}\n", []),
    ("ordering_comment_does_not_leak_past_block", "rust/src/util/x.rs",
     "use std::sync::atomic::{AtomicUsize, Ordering};\npub fn f(a: &AtomicUsize) -> usize {\n    // ordering: covers this fn only\n    a.load(Ordering::Relaxed)\n}\npub fn g(a: &AtomicUsize) -> usize {\n    a.load(Ordering::Relaxed)\n}\n", ["ordering-note"]),
    ("seqcst_needs_note_too", "rust/src/util/x.rs",
     "use std::sync::atomic::{AtomicUsize, Ordering};\npub fn f(a: &AtomicUsize) -> usize {\n    a.load(Ordering::SeqCst)\n}\n", ["ordering-note"]),
    ("cmp_ordering_is_not_atomic", "rust/src/util/x.rs",
     "use std::cmp::Ordering;\npub fn f(a: i32) -> Ordering {\n    if a < 0 { Ordering::Less } else { Ordering::Greater }\n}\n", []),
    ("lock_across_send_fails", "rust/src/serve/x.rs",
     "pub fn f(m: &std::sync::Mutex<u32>, tx: &std::sync::mpsc::Sender<u32>) {\n    let g = m.lock().unwrap();\n    tx.send(*g).ok();\n}\n", ["lock-across"]),
    ("drop_before_send_passes", "rust/src/serve/x.rs",
     "pub fn f(m: &std::sync::Mutex<u32>, tx: &std::sync::mpsc::Sender<u32>) {\n    let g = m.lock().unwrap();\n    let v = *g;\n    drop(g);\n    tx.send(v).ok();\n}\n", []),
    ("scope_before_send_passes", "rust/src/serve/x.rs",
     "pub fn f(m: &std::sync::Mutex<u32>, tx: &std::sync::mpsc::Sender<u32>) {\n    let v = {\n        let g = m.lock().unwrap();\n        *g\n    };\n    tx.send(v).ok();\n}\n", []),
    ("view_guard_across_export_fails", "rust/src/kvcache/x.rs",
     "pub fn f(store: &crate::kvcache::ShardedKvCache) {\n    let view = store.layer(0);\n    store.export_seq(7);\n}\n", ["lock-across"]),
    ("shard_guard_across_pool_publish_fails", "rust/src/kvcache/x.rs",
     "pub fn f(store: &crate::kvcache::ShardedKvCache, pool: &crate::kvcache::PrefixPool) {\n    let view = store.layer(0);\n    pool.publish(7, Vec::new());\n}\n", ["lock-across"]),
    ("scoped_guard_before_pool_probe_passes", "rust/src/kvcache/x.rs",
     "pub fn f(store: &crate::kvcache::ShardedKvCache, pool: &crate::kvcache::PrefixPool) {\n    {\n        let view = store.layer(0);\n        let _ = view;\n    }\n    pool.probe(7);\n}\n", []),
    ("registry_guard_across_spill_fails", "rust/src/kvcache/x.rs",
     "pub fn f(m: &std::sync::Mutex<u32>, file: &crate::kvcache::SpillFile) {\n    let g = m.lock().unwrap();\n    let _ = file.spill(&[]);\n    let _ = g;\n}\n", ["lock-across"]),
    ("guard_dropped_before_page_in_passes", "rust/src/kvcache/x.rs",
     "pub fn f(m: &std::sync::Mutex<u64>, file: &crate::kvcache::SpillFile) {\n    let g = m.lock().unwrap();\n    let id = *g;\n    drop(g);\n    let _ = file.page_in(id);\n}\n", []),
    ("annotated_guard_across_page_in_passes", "rust/src/kvcache/x.rs",
     "pub fn f(m: &std::sync::Mutex<u64>, file: &crate::kvcache::SpillFile) {\n    let g = m.lock().unwrap();\n    // audit: allow(lock_across): single-threaded recovery path\n    let _ = file.page_in(*g);\n}\n", []),
    ("scrutinee_temporary_not_tracked", "rust/src/coordinator/x.rs",
     "pub fn f(rx: &std::sync::Mutex<std::sync::mpsc::Receiver<u32>>, tx: &std::sync::mpsc::Sender<u32>) {\n    let job = match rx.lock().unwrap().recv() { Ok(j) => j, Err(_) => return };\n    tx.send(job).ok();\n}\n", []),
    ("lock_across_outside_guarded_dirs_ignored", "rust/src/runtime/x.rs",
     "pub fn f(m: &std::sync::Mutex<u32>, tx: &std::sync::mpsc::Sender<u32>) {\n    let g = m.lock().unwrap();\n    tx.send(*g).ok();\n}\n", []),
    ("hot_path_unwrap_fails", "rust/src/serve/x.rs",
     "pub fn f(v: Option<u32>) -> u32 {\n    v.unwrap()\n}\n", ["unwrap-hot"]),
    ("hot_path_expect_fails", "rust/src/kvcache/x.rs",
     "pub fn f(v: Option<u32>) -> u32 {\n    v.expect(\"always set\")\n}\n", ["unwrap-hot"]),
    ("poison_idiom_allowed", "rust/src/serve/x.rs",
     "pub fn f(m: &std::sync::Mutex<u32>) -> u32 {\n    *m.lock().unwrap()\n}\n", []),
    ("annotated_expect_allowed", "rust/src/serve/x.rs",
     "pub fn f(v: Option<u32>) -> u32 {\n    // audit: allow(expect): populated by constructor\n    v.expect(\"set in new()\")\n}\n", []),
    ("cfg_test_mod_exempt", "rust/src/serve/x.rs",
     "pub fn ok() {}\n#[cfg(test)]\nmod tests {\n    use std::sync::atomic::{AtomicUsize, Ordering};\n    fn f(a: &AtomicUsize, v: Option<u32>) -> u32 {\n        a.load(Ordering::SeqCst);\n        unsafe { std::hint::unreachable_unchecked() };\n        v.unwrap()\n    }\n}\n", []),
    ("bare_catch_unwind_fails", "rust/src/serve/x.rs",
     "pub fn f(work: fn()) {\n    let _ = std::panic::catch_unwind(work);\n}\n", ["unwind-safety"]),
    ("annotated_catch_unwind_passes", "rust/src/serve/x.rs",
     "pub fn f(work: fn()) {\n    // unwind-safety: work owns every value it mutates; nothing observable survives the unwind\n    let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(work));\n}\n", []),
    ("string_and_comment_tokens_ignored", "rust/src/serve/x.rs",
     "// this comment mentions unsafe and Ordering::Relaxed\npub fn f() -> &'static str {\n    \"unsafe { Ordering::Relaxed }.unwrap()\"\n}\n", []),
]


def run_fixtures():
    failures = []
    for name, path, source, expect in FIXTURES:
        got = [v[2] for v in audit_source(Source(path, source))]
        if got != expect:
            failures.append(f"{name}: expected {expect}, got {got}")
    return failures


def main(argv):
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    self_test = False
    i = 0
    while i < len(argv):
        if argv[i] == "--root":
            i += 1
            root = argv[i]
        elif argv[i] == "--self-test":
            self_test = True
        else:
            print(f"unknown argument: {argv[i]}", file=sys.stderr)
            return 2
        i += 1

    if self_test:
        failures = run_fixtures()
        if failures:
            for f in failures:
                print(f"audit self-test FAIL: {f}", file=sys.stderr)
            return 1
        print(f"audit self-test: {len(FIXTURES)} fixtures passed")
        return 0

    files = []
    for sub in ("rust/src", "xtask/src"):
        d = os.path.join(root, sub)
        if not os.path.isdir(d):
            print(f"audit: missing source dir {d}", file=sys.stderr)
            return 2
        for dirpath, _, names in os.walk(d):
            for nm in sorted(names):
                if nm.endswith(".rs"):
                    files.append(os.path.join(dirpath, nm))
    files.sort()

    violations = []
    for path in files:
        with open(path, encoding="utf-8") as fh:
            text = fh.read()
        rel = os.path.relpath(path, root).replace("\\", "/")
        src = Source(rel, text)
        violations.extend(audit_source(src))
        check_lib_attrs(src, violations)

    if not violations:
        print(f"audit: {len(files)} files clean")
        return 0
    for p, line, rule, msg in violations:
        print(f"{p}:{line}: [{rule}] {msg}")
    print(f"audit: {len(violations)} violation(s) across {len(files)} files")
    return 1


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
