//! Comment- and string-aware source scanning for the audit pass.
//!
//! The offline crate universe cannot vendor `syn`, so the audit works on
//! a **masked** view of each source file: every byte inside a string
//! literal or comment is blanked to a space (newlines preserved, so byte
//! offsets and line numbers are identical to the original). Token
//! searches on the masked text therefore cannot be fooled by `"unsafe"`
//! inside a string or `Ordering::Relaxed` inside a doc comment.
//! Comments themselves are recorded separately with line / byte-offset /
//! trailing metadata, because the audit rules are *about* comments: a
//! `// SAFETY:` or `// ordering:` annotation either sits on the site's
//! own line or in the contiguous comment/attribute block above it.
//!
//! The scanner also precomputes:
//! - a per-byte brace-depth array (`depth[i]` = depth *before* byte `i`),
//!   used for `// ordering:` coverage intervals (a standalone ordering
//!   comment covers every atomic site from its line to the end of its
//!   enclosing brace block) and for lock-guard liveness;
//! - `#[cfg(test)] mod` spans, which every rule skips.
//!
//! Known limitations (accepted for a token-level pass): temporaries in a
//! `match` scrutinee (`match rx.lock().unwrap().recv() { .. }`) extend
//! the guard's life to the end of the match but are not tracked — only
//! *named* `let` guard bindings are; macro-generated code is not
//! expanded.

/// A single `//` or `/* */` comment, with enough metadata to apply the
/// adjacency rules.
#[derive(Debug)]
pub struct Comment {
    /// 1-based line of the comment's first byte.
    pub line: usize,
    /// Byte offset of the comment opener.
    pub pos: usize,
    /// Full original text of the comment (including delimiters).
    pub text: String,
    /// True when code precedes the comment on its line (a trailing
    /// comment annotates the statement it shares a line with).
    pub trailing: bool,
}

/// A scanned source file.
pub struct Source {
    /// Repo-relative display path.
    pub path: String,
    /// Original text.
    pub text: String,
    /// Same length as `text`, with string/comment bytes blanked.
    pub masked: String,
    pub comments: Vec<Comment>,
    /// Byte offset of each line start; `line_starts[0] == 0`.
    pub line_starts: Vec<usize>,
    /// `depth[i]` = brace depth before byte `i`; length `text.len() + 1`.
    pub depth: Vec<u32>,
    /// Byte ranges of `#[cfg(test)] mod … { … }` items.
    pub test_spans: Vec<(usize, usize)>,
}

impl Source {
    pub fn scan(path: &str, text: &str) -> Source {
        // Pass 1: mask strings and comments, record comments.
        let bytes = text.as_bytes();
        let n = bytes.len();
        let mut masked = bytes.to_vec();
        let mut comments = Vec::new();
        let mut line = 1usize;
        let mut line_has_code = false;

        let mut i = 0;
        while i < n {
            match bytes[i] {
                b'\n' => {
                    line += 1;
                    line_has_code = false;
                    i += 1;
                }
                b'/' if i + 1 < n && bytes[i + 1] == b'/' => {
                    let start = i;
                    while i < n && bytes[i] != b'\n' {
                        masked[i] = b' ';
                        i += 1;
                    }
                    comments.push(Comment {
                        line,
                        pos: start,
                        text: text[start..i].to_string(),
                        trailing: line_has_code,
                    });
                }
                b'/' if i + 1 < n && bytes[i + 1] == b'*' => {
                    let start = i;
                    let start_line = line;
                    let trailing = line_has_code;
                    let mut nest = 1;
                    masked[i] = b' ';
                    masked[i + 1] = b' ';
                    i += 2;
                    while i < n && nest > 0 {
                        if bytes[i] == b'/' && i + 1 < n && bytes[i + 1] == b'*' {
                            nest += 1;
                            masked[i] = b' ';
                            masked[i + 1] = b' ';
                            i += 2;
                        } else if bytes[i] == b'*' && i + 1 < n && bytes[i + 1] == b'/' {
                            nest -= 1;
                            masked[i] = b' ';
                            masked[i + 1] = b' ';
                            i += 2;
                        } else {
                            if bytes[i] == b'\n' {
                                line += 1;
                            } else {
                                masked[i] = b' ';
                            }
                            i += 1;
                        }
                    }
                    comments.push(Comment {
                        line: start_line,
                        pos: start,
                        text: text[start..i.min(n)].to_string(),
                        trailing,
                    });
                }
                b'"' => {
                    line_has_code = true;
                    masked[i] = b' ';
                    i += 1;
                    while i < n {
                        if bytes[i] == b'\\' && i + 1 < n {
                            masked[i] = b' ';
                            if bytes[i + 1] != b'\n' {
                                masked[i + 1] = b' ';
                            } else {
                                line += 1;
                            }
                            i += 2;
                        } else if bytes[i] == b'"' {
                            masked[i] = b' ';
                            i += 1;
                            break;
                        } else {
                            if bytes[i] == b'\n' {
                                line += 1;
                            } else {
                                masked[i] = b' ';
                            }
                            i += 1;
                        }
                    }
                }
                b'r' if raw_string_hashes(bytes, i).is_some() => {
                    line_has_code = true;
                    let hashes = raw_string_hashes(bytes, i).unwrap();
                    let open_len = 1 + hashes + 1; // r##"
                    for k in 0..open_len {
                        masked[i + k] = b' ';
                    }
                    i += open_len;
                    while i < n {
                        if bytes[i] == b'"' && has_hashes(bytes, i + 1, hashes) {
                            for k in 0..=hashes {
                                masked[i + k] = b' ';
                            }
                            i += 1 + hashes;
                            break;
                        }
                        if bytes[i] == b'\n' {
                            line += 1;
                        } else {
                            masked[i] = b' ';
                        }
                        i += 1;
                    }
                }
                b'\'' => {
                    line_has_code = true;
                    // Distinguish lifetimes (`'a`) from char literals
                    // (`'x'`, `'\n'`).
                    if i + 1 < n && bytes[i + 1] == b'\\' {
                        masked[i] = b' ';
                        i += 1;
                        while i < n && bytes[i] != b'\'' {
                            masked[i] = b' ';
                            i += 1;
                        }
                        if i < n {
                            masked[i] = b' ';
                            i += 1;
                        }
                    } else if char_literal_len(bytes, i).is_some() {
                        let len = char_literal_len(bytes, i).unwrap();
                        for k in 0..len {
                            masked[i + k] = b' ';
                        }
                        i += len;
                    } else {
                        // Lifetime: leave as-is.
                        i += 1;
                    }
                }
                b' ' | b'\t' | b'\r' => {
                    i += 1;
                }
                _ => {
                    line_has_code = true;
                    i += 1;
                }
            }
        }

        // Pass 2: line starts and brace depth over the masked bytes.
        let mut line_starts = vec![0usize];
        let mut depth = Vec::with_capacity(n + 1);
        let mut cur: u32 = 0;
        for (j, &b) in masked.iter().enumerate() {
            depth.push(cur);
            match b {
                b'\n' => line_starts.push(j + 1),
                b'{' => cur += 1,
                b'}' => cur = cur.saturating_sub(1),
                _ => {}
            }
        }
        depth.push(cur);

        let masked = String::from_utf8(masked).expect("masking replaces whole bytes with ASCII");
        let mut src = Source {
            path: path.to_string(),
            text: text.to_string(),
            masked,
            comments,
            line_starts,
            depth,
            test_spans: Vec::new(),
        };
        src.test_spans = src.find_test_spans();
        src
    }

    /// 1-based line number of a byte offset.
    pub fn line_of(&self, pos: usize) -> usize {
        match self.line_starts.binary_search(&pos) {
            Ok(idx) => idx + 1,
            Err(idx) => idx,
        }
    }

    /// Masked content of a 1-based line (without trailing newline).
    pub fn masked_line(&self, line: usize) -> &str {
        let start = self.line_starts[line - 1];
        let end = self
            .line_starts
            .get(line)
            .map(|&e| e.saturating_sub(1))
            .unwrap_or(self.masked.len());
        &self.masked[start..end.max(start)]
    }

    pub fn num_lines(&self) -> usize {
        self.line_starts.len()
    }

    /// Whether a byte offset falls inside a `#[cfg(test)] mod` block.
    pub fn in_test(&self, pos: usize) -> bool {
        self.test_spans.iter().any(|&(s, e)| pos >= s && pos < e)
    }

    /// End of the brace block enclosing `pos`: the first offset after
    /// `pos` whose depth drops below `depth[pos]` (file end if none).
    pub fn block_end(&self, pos: usize) -> usize {
        let d = self.depth[pos];
        for j in pos + 1..self.depth.len() {
            if self.depth[j] < d {
                return j;
            }
        }
        self.text.len()
    }

    /// Comments on the given 1-based line.
    pub fn comments_on_line(&self, line: usize) -> impl Iterator<Item = &Comment> {
        self.comments.iter().filter(move |c| c.line == line)
    }

    /// True when a comment matching `pred` sits on the site's own line or
    /// in the contiguous comment/attribute block immediately above it.
    /// Blank lines and code lines terminate the upward walk; attribute
    /// lines (`#[…]`) are skipped so `// SAFETY:` above
    /// `#[target_feature]` still reaches the `unsafe fn`.
    pub fn annotated(&self, site_line: usize, pred: impl Fn(&str) -> bool) -> bool {
        if self.comments_on_line(site_line).any(|c| pred(&c.text)) {
            return true;
        }
        let mut l = site_line;
        while l > 1 {
            l -= 1;
            let code_empty = self.masked_line(l).trim().is_empty();
            let line_comments: Vec<&Comment> =
                self.comments.iter().filter(|c| c.line == l).collect();
            if code_empty && !line_comments.is_empty() {
                // Comment-only line.
                if line_comments.iter().any(|c| pred(&c.text)) {
                    return true;
                }
                continue;
            }
            let code = self.masked_line(l).trim();
            if code.starts_with("#[") || code.starts_with("#![") {
                continue;
            }
            return false; // blank line or plain code: block ends
        }
        false
    }

    fn find_test_spans(&self) -> Vec<(usize, usize)> {
        let mut spans = Vec::new();
        let needle = "#[cfg(test)]";
        let mut from = 0;
        while let Some(rel) = self.masked[from..].find(needle) {
            let attr_pos = from + rel;
            from = attr_pos + needle.len();
            // Only a following `mod … {` item forms a skip span; a
            // `#[cfg(test)] use …` line does not.
            let rest = &self.masked[attr_pos + needle.len()..];
            let Some(brace_rel) = rest.find('{') else { continue };
            let between = &rest[..brace_rel];
            if !between.split_whitespace().any(|t| t == "mod") {
                continue;
            }
            let open = attr_pos + needle.len() + brace_rel;
            let end = self.block_after_open(open);
            spans.push((attr_pos, end));
            from = end;
        }
        spans
    }

    /// Offset just past the `}` matching the `{` at `open`.
    fn block_after_open(&self, open: usize) -> usize {
        let d = self.depth[open];
        for j in open + 1..self.depth.len() {
            if self.depth[j] == d {
                return j;
            }
        }
        self.text.len()
    }
}

/// If `bytes[i..]` opens a raw string (`r"`, `r#"`, …) starting at the
/// `r`, return the number of hashes.
fn raw_string_hashes(bytes: &[u8], i: usize) -> Option<usize> {
    // `r` must not be the tail of an identifier.
    if i > 0 && (bytes[i - 1].is_ascii_alphanumeric() || bytes[i - 1] == b'_') {
        return None;
    }
    let mut j = i + 1;
    let mut hashes = 0;
    while j < bytes.len() && bytes[j] == b'#' {
        hashes += 1;
        j += 1;
    }
    if j < bytes.len() && bytes[j] == b'"' {
        Some(hashes)
    } else {
        None
    }
}

fn has_hashes(bytes: &[u8], from: usize, count: usize) -> bool {
    if from + count > bytes.len() {
        return false;
    }
    bytes[from..from + count].iter().all(|&b| b == b'#')
}

/// Length of a plain (non-escaped) char literal at `i` (the opening
/// quote), or None if this is a lifetime. Handles multi-byte UTF-8 chars.
fn char_literal_len(bytes: &[u8], i: usize) -> Option<usize> {
    let mut j = i + 1;
    if j >= bytes.len() || bytes[j] == b'\'' {
        return None;
    }
    // Advance one UTF-8 scalar.
    j += 1;
    while j < bytes.len() && (bytes[j] & 0xC0) == 0x80 {
        j += 1;
    }
    if j < bytes.len() && bytes[j] == b'\'' {
        Some(j + 1 - i)
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn masks_strings_and_comments() {
        let src = Source::scan("t.rs", "let a = \"unsafe\"; // unsafe\nunsafe {}\n");
        assert!(!src.masked[..src.line_starts[1]].contains("unsafe"));
        assert!(src.masked[src.line_starts[1]..].contains("unsafe"));
        assert_eq!(src.comments.len(), 1);
        assert!(src.comments[0].trailing);
    }

    #[test]
    fn depth_tracks_braces_not_strings() {
        let src = Source::scan("t.rs", "fn f() { let s = \"{{{\"; }\n");
        assert_eq!(*src.depth.last().unwrap(), 0);
    }

    #[test]
    fn cfg_test_mod_span_found() {
        let text = "fn a() {}\n#[cfg(test)]\nmod tests {\n  fn b() {}\n}\nfn c() {}\n";
        let src = Source::scan("t.rs", text);
        assert_eq!(src.test_spans.len(), 1);
        let b_pos = text.find("fn b").unwrap();
        let c_pos = text.find("fn c").unwrap();
        assert!(src.in_test(b_pos));
        assert!(!src.in_test(c_pos));
    }

    #[test]
    fn annotated_walks_over_attributes() {
        let text = "// SAFETY: fine\n#[target_feature(enable = \"avx2\")]\nunsafe fn f() {}\n";
        let src = Source::scan("t.rs", text);
        assert!(src.annotated(3, |c| c.contains("SAFETY:")));
        assert!(!src.annotated(3, |c| c.contains("ordering:")));
    }

    #[test]
    fn blank_line_breaks_annotation_block() {
        let text = "// SAFETY: stale\n\nunsafe fn f() {}\n";
        let src = Source::scan("t.rs", text);
        assert!(!src.annotated(3, |c| c.contains("SAFETY:")));
    }

    #[test]
    fn lifetimes_do_not_eat_source() {
        let src = Source::scan("t.rs", "fn f<'a>(x: &'a str) -> &'a str { x }\nunsafe {}\n");
        assert!(src.masked.contains("unsafe"));
    }

    #[test]
    fn raw_strings_masked() {
        let src = Source::scan("t.rs", "let s = r#\"unsafe { Ordering::Relaxed }\"#;\n");
        assert!(!src.masked.contains("unsafe"));
        assert!(!src.masked.contains("Ordering"));
    }

    #[test]
    fn char_literal_with_brace_does_not_break_depth() {
        let src = Source::scan("t.rs", "fn f() { let c = '{'; }\n");
        assert_eq!(*src.depth.last().unwrap(), 0);
    }
}
