//! Seeded-violation fixtures: the audit must *demonstrably fail* on a
//! bare unsafe block, an unannotated Relaxed, a lock held across a send,
//! a hot-path unwrap, and a bare catch_unwind — and must stay quiet on
//! the annotated/scoped versions of the same code. `cargo xtask audit
//! --self-test` runs these (CI does, before trusting the clean run on
//! the real tree), and the crate's unit tests run the same table.

use crate::audit::audit_source;
use crate::scan::Source;

struct Fixture {
    name: &'static str,
    /// Synthetic repo-relative path — chosen to opt in/out of the
    /// path-scoped rules.
    path: &'static str,
    source: &'static str,
    /// Exact multiset of rules expected to fire, in line order.
    expect: &'static [&'static str],
}

const FIXTURES: &[Fixture] = &[
    Fixture {
        name: "bare_unsafe_block_fails",
        path: "rust/src/util/x.rs",
        source: "pub fn f(p: *const u8) -> u8 {\n    unsafe { *p }\n}\n",
        expect: &["unsafe-safety"],
    },
    Fixture {
        name: "commented_unsafe_block_passes",
        path: "rust/src/util/x.rs",
        source: "pub fn f(p: *const u8) -> u8 {\n    // SAFETY: caller contract\n    unsafe { *p }\n}\n",
        expect: &[],
    },
    Fixture {
        name: "safety_above_target_feature_passes",
        path: "rust/src/util/x.rs",
        source: "// SAFETY: caller checks avx2\n#[target_feature(enable = \"avx2\")]\nunsafe fn f() {}\n",
        expect: &[],
    },
    Fixture {
        name: "unannotated_relaxed_fails",
        path: "rust/src/util/x.rs",
        source: "use std::sync::atomic::{AtomicUsize, Ordering};\npub fn f(a: &AtomicUsize) -> usize {\n    a.load(Ordering::Relaxed)\n}\n",
        expect: &["ordering-note"],
    },
    Fixture {
        name: "trailing_ordering_comment_passes",
        path: "rust/src/util/x.rs",
        source: "use std::sync::atomic::{AtomicUsize, Ordering};\npub fn f(a: &AtomicUsize) -> usize {\n    a.load(Ordering::Relaxed) // ordering: pure counter\n}\n",
        expect: &[],
    },
    Fixture {
        name: "block_scoped_ordering_comment_covers_cluster",
        path: "rust/src/util/x.rs",
        source: "use std::sync::atomic::{AtomicUsize, Ordering};\npub fn f(a: &AtomicUsize) -> usize {\n    // ordering: both loads are monotonic gauges\n    let x = a.load(Ordering::Relaxed);\n    x + a.load(Ordering::Relaxed)\n}\n",
        expect: &[],
    },
    Fixture {
        name: "ordering_comment_does_not_leak_past_block",
        path: "rust/src/util/x.rs",
        source: "use std::sync::atomic::{AtomicUsize, Ordering};\npub fn f(a: &AtomicUsize) -> usize {\n    // ordering: covers this fn only\n    a.load(Ordering::Relaxed)\n}\npub fn g(a: &AtomicUsize) -> usize {\n    a.load(Ordering::Relaxed)\n}\n",
        expect: &["ordering-note"],
    },
    Fixture {
        name: "seqcst_needs_note_too",
        path: "rust/src/util/x.rs",
        source: "use std::sync::atomic::{AtomicUsize, Ordering};\npub fn f(a: &AtomicUsize) -> usize {\n    a.load(Ordering::SeqCst)\n}\n",
        expect: &["ordering-note"],
    },
    Fixture {
        name: "cmp_ordering_is_not_atomic",
        path: "rust/src/util/x.rs",
        source: "use std::cmp::Ordering;\npub fn f(a: i32) -> Ordering {\n    if a < 0 { Ordering::Less } else { Ordering::Greater }\n}\n",
        expect: &[],
    },
    Fixture {
        name: "lock_across_send_fails",
        path: "rust/src/serve/x.rs",
        source: "pub fn f(m: &std::sync::Mutex<u32>, tx: &std::sync::mpsc::Sender<u32>) {\n    let g = m.lock().unwrap();\n    tx.send(*g).ok();\n}\n",
        expect: &["lock-across"],
    },
    Fixture {
        name: "drop_before_send_passes",
        path: "rust/src/serve/x.rs",
        source: "pub fn f(m: &std::sync::Mutex<u32>, tx: &std::sync::mpsc::Sender<u32>) {\n    let g = m.lock().unwrap();\n    let v = *g;\n    drop(g);\n    tx.send(v).ok();\n}\n",
        expect: &[],
    },
    Fixture {
        name: "scope_before_send_passes",
        path: "rust/src/serve/x.rs",
        source: "pub fn f(m: &std::sync::Mutex<u32>, tx: &std::sync::mpsc::Sender<u32>) {\n    let v = {\n        let g = m.lock().unwrap();\n        *g\n    };\n    tx.send(v).ok();\n}\n",
        expect: &[],
    },
    Fixture {
        name: "view_guard_across_export_fails",
        path: "rust/src/kvcache/x.rs",
        source: "pub fn f(store: &crate::kvcache::ShardedKvCache) {\n    let view = store.layer(0);\n    store.export_seq(7);\n}\n",
        expect: &["lock-across"],
    },
    Fixture {
        name: "shard_guard_across_pool_publish_fails",
        path: "rust/src/kvcache/x.rs",
        source: "pub fn f(store: &crate::kvcache::ShardedKvCache, pool: &crate::kvcache::PrefixPool) {\n    let view = store.layer(0);\n    pool.publish(7, Vec::new());\n}\n",
        expect: &["lock-across"],
    },
    Fixture {
        name: "scoped_guard_before_pool_probe_passes",
        path: "rust/src/kvcache/x.rs",
        source: "pub fn f(store: &crate::kvcache::ShardedKvCache, pool: &crate::kvcache::PrefixPool) {\n    {\n        let view = store.layer(0);\n        let _ = view;\n    }\n    pool.probe(7);\n}\n",
        expect: &[],
    },
    Fixture {
        name: "registry_guard_across_spill_fails",
        path: "rust/src/kvcache/x.rs",
        source: "pub fn f(m: &std::sync::Mutex<u32>, file: &crate::kvcache::SpillFile) {\n    let g = m.lock().unwrap();\n    let _ = file.spill(&[]);\n    let _ = g;\n}\n",
        expect: &["lock-across"],
    },
    Fixture {
        name: "guard_dropped_before_page_in_passes",
        path: "rust/src/kvcache/x.rs",
        source: "pub fn f(m: &std::sync::Mutex<u64>, file: &crate::kvcache::SpillFile) {\n    let g = m.lock().unwrap();\n    let id = *g;\n    drop(g);\n    let _ = file.page_in(id);\n}\n",
        expect: &[],
    },
    Fixture {
        name: "annotated_guard_across_page_in_passes",
        path: "rust/src/kvcache/x.rs",
        source: "pub fn f(m: &std::sync::Mutex<u64>, file: &crate::kvcache::SpillFile) {\n    let g = m.lock().unwrap();\n    // audit: allow(lock_across): single-threaded recovery path\n    let _ = file.page_in(*g);\n}\n",
        expect: &[],
    },
    Fixture {
        name: "scrutinee_temporary_not_tracked",
        path: "rust/src/coordinator/x.rs",
        source: "pub fn f(rx: &std::sync::Mutex<std::sync::mpsc::Receiver<u32>>, tx: &std::sync::mpsc::Sender<u32>) {\n    let job = match rx.lock().unwrap().recv() { Ok(j) => j, Err(_) => return };\n    tx.send(job).ok();\n}\n",
        expect: &[],
    },
    Fixture {
        name: "lock_across_outside_guarded_dirs_ignored",
        path: "rust/src/runtime/x.rs",
        source: "pub fn f(m: &std::sync::Mutex<u32>, tx: &std::sync::mpsc::Sender<u32>) {\n    let g = m.lock().unwrap();\n    tx.send(*g).ok();\n}\n",
        expect: &[],
    },
    Fixture {
        name: "hot_path_unwrap_fails",
        path: "rust/src/serve/x.rs",
        source: "pub fn f(v: Option<u32>) -> u32 {\n    v.unwrap()\n}\n",
        expect: &["unwrap-hot"],
    },
    Fixture {
        name: "hot_path_expect_fails",
        path: "rust/src/kvcache/x.rs",
        source: "pub fn f(v: Option<u32>) -> u32 {\n    v.expect(\"always set\")\n}\n",
        expect: &["unwrap-hot"],
    },
    Fixture {
        name: "poison_idiom_allowed",
        path: "rust/src/serve/x.rs",
        source: "pub fn f(m: &std::sync::Mutex<u32>) -> u32 {\n    *m.lock().unwrap()\n}\n",
        expect: &[],
    },
    Fixture {
        name: "annotated_expect_allowed",
        path: "rust/src/serve/x.rs",
        source: "pub fn f(v: Option<u32>) -> u32 {\n    // audit: allow(expect): populated by constructor\n    v.expect(\"set in new()\")\n}\n",
        expect: &[],
    },
    Fixture {
        name: "cfg_test_mod_exempt",
        path: "rust/src/serve/x.rs",
        source: "pub fn ok() {}\n#[cfg(test)]\nmod tests {\n    use std::sync::atomic::{AtomicUsize, Ordering};\n    fn f(a: &AtomicUsize, v: Option<u32>) -> u32 {\n        a.load(Ordering::SeqCst);\n        unsafe { std::hint::unreachable_unchecked() };\n        v.unwrap()\n    }\n}\n",
        expect: &[],
    },
    Fixture {
        name: "bare_catch_unwind_fails",
        path: "rust/src/serve/x.rs",
        source: "pub fn f(work: fn()) {\n    let _ = std::panic::catch_unwind(work);\n}\n",
        expect: &["unwind-safety"],
    },
    Fixture {
        name: "annotated_catch_unwind_passes",
        path: "rust/src/serve/x.rs",
        source: "pub fn f(work: fn()) {\n    // unwind-safety: work owns every value it mutates; nothing observable survives the unwind\n    let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(work));\n}\n",
        expect: &[],
    },
    Fixture {
        name: "string_and_comment_tokens_ignored",
        path: "rust/src/serve/x.rs",
        source: "// this comment mentions unsafe and Ordering::Relaxed\npub fn f() -> &'static str {\n    \"unsafe { Ordering::Relaxed }.unwrap()\"\n}\n",
        expect: &[],
    },
];

/// Run every fixture; return human-readable failure lines (empty = pass).
pub fn run_fixtures() -> Vec<String> {
    let mut failures = Vec::new();
    for fx in FIXTURES {
        let src = Source::scan(fx.path, fx.source);
        let got: Vec<&'static str> = audit_source(&src).iter().map(|v| v.rule).collect();
        if got != fx.expect {
            failures.push(format!(
                "{}: expected {:?}, got {:?}",
                fx.name, fx.expect, got
            ));
        }
    }
    failures
}

pub fn fixture_count() -> usize {
    FIXTURES.len()
}
