//! `cargo xtask` — repo automation. One subcommand today:
//!
//! ```text
//! cargo xtask audit [--root <dir>] [--self-test]
//! ```
//!
//! `audit` lints `rust/src` and `xtask/src` for the concurrency
//! invariants documented in DESIGN.md §Correctness tooling (SAFETY
//! comments on unsafe, ordering justifications on atomics, no lock
//! guards across blocking boundaries, no hot-path unwrap/expect,
//! unwind-safety arguments on catch_unwind/AssertUnwindSafe sites).
//! Exit status: 0 clean, 1 violations found, 2 usage/IO error.
//!
//! `--self-test` runs the seeded-violation fixtures instead of the real
//! tree: the audit must fail on a bare unsafe block, an unannotated
//! Relaxed, a lock held across a send, a hot-path unwrap, and a bare
//! catch_unwind. CI runs the self-test first so a silently-broken
//! linter cannot green-light the tree.
#![deny(unsafe_op_in_unsafe_fn)]

mod audit;
mod scan;
mod selftest;

use std::path::{Path, PathBuf};
use std::process::ExitCode;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut cmd = None;
    let mut root = default_root();
    let mut self_test = false;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--root" => {
                i += 1;
                let Some(dir) = args.get(i) else {
                    eprintln!("--root requires a path");
                    return ExitCode::from(2);
                };
                root = PathBuf::from(dir);
            }
            "--self-test" => self_test = true,
            a if !a.starts_with('-') && cmd.is_none() => cmd = Some(a.to_string()),
            other => {
                eprintln!("unknown argument: {other}");
                return ExitCode::from(2);
            }
        }
        i += 1;
    }

    match cmd.as_deref() {
        Some("audit") => {
            if self_test {
                run_self_test()
            } else {
                run_audit(&root)
            }
        }
        Some(other) => {
            eprintln!("unknown command: {other}\nusage: cargo xtask audit [--root <dir>] [--self-test]");
            ExitCode::from(2)
        }
        None => {
            eprintln!("usage: cargo xtask audit [--root <dir>] [--self-test]");
            ExitCode::from(2)
        }
    }
}

/// The workspace root: xtask always lives one level below it.
fn default_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).parent().map(Path::to_path_buf).unwrap_or_default()
}

fn run_self_test() -> ExitCode {
    let failures = selftest::run_fixtures();
    if failures.is_empty() {
        println!("audit self-test: {} fixtures passed", selftest::fixture_count());
        ExitCode::SUCCESS
    } else {
        for f in &failures {
            eprintln!("audit self-test FAIL: {f}");
        }
        eprintln!(
            "audit self-test: {}/{} fixtures failed",
            failures.len(),
            selftest::fixture_count()
        );
        ExitCode::FAILURE
    }
}

fn run_audit(root: &Path) -> ExitCode {
    let mut files = Vec::new();
    for sub in ["rust/src", "xtask/src"] {
        let dir = root.join(sub);
        if !dir.is_dir() {
            eprintln!("audit: missing source dir {}", dir.display());
            return ExitCode::from(2);
        }
        collect_rs(&dir, &mut files);
    }
    files.sort();

    let mut violations = Vec::new();
    let mut scanned = 0usize;
    for path in &files {
        let text = match std::fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("audit: cannot read {}: {e}", path.display());
                return ExitCode::from(2);
            }
        };
        let rel = path
            .strip_prefix(root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        let src = scan::Source::scan(&rel, &text);
        violations.extend(audit::audit_source(&src));
        audit::check_lib_attrs(&src, &mut violations);
        scanned += 1;
    }

    if violations.is_empty() {
        println!("audit: {scanned} files clean");
        ExitCode::SUCCESS
    } else {
        for v in &violations {
            println!("{v}");
        }
        println!("audit: {} violation(s) across {scanned} files", violations.len());
        ExitCode::FAILURE
    }
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(entries) = std::fs::read_dir(dir) else { return };
    for entry in entries.flatten() {
        let path = entry.path();
        if path.is_dir() {
            collect_rs(&path, out);
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
}
