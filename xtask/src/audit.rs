//! The audit rules: repo-wide concurrency/correctness invariants.
//!
//! Five rules, all operating on the masked view built by [`crate::scan`]:
//!
//! 1. **unsafe-safety** — every `unsafe` keyword (block, fn, impl, trait)
//!    carries a `// SAFETY:` comment on its line or in the contiguous
//!    comment/attribute block above it (doc `# Safety` sections count).
//! 2. **ordering-note** — every `Ordering::{Relaxed,Acquire,Release,
//!    AcqRel,SeqCst}` site carries an `// ordering:` justification: a
//!    trailing comment on the same line, or a standalone `// ordering:`
//!    comment earlier in the same brace block (coverage runs from the
//!    comment to the end of its enclosing block, so one comment can
//!    justify a cluster of related sites — e.g. a telemetry snapshot).
//! 3. **lock-across** — in `coordinator/`, `kvcache/`, and `serve/`, no
//!    *named* lock/view guard (`let g = ….lock()/.read()/.write()/
//!    .layer(…)`) is live across a blocking boundary: channel `.send(` /
//!    `.try_send(`, `Backend::execute`, `export_seq`/`import_seq`,
//!    the prefix-pool's `.probe(`/`.publish(` (both take the pool mutex;
//!    entering them with a shard guard held inverts the lock order
//!    against the publish path, which takes shard locks to seal blocks),
//!    or the session tier's spill-file `.spill(`/`.page_in(` (blocking
//!    file I/O — the tier plans demotions under its registry lock and
//!    executes them guard-free; holding any lock across them stalls
//!    every replica behind disk latency).
//!    Guards die at `drop(g)`, at rebinding, or when their brace block
//!    closes. Escape hatch: `// audit: allow(lock_across): reason`.
//! 4. **unwrap-hot** — no `.unwrap()` / `.expect(` in non-test hot-path
//!    modules (`coordinator/`, `kvcache/`, `serve/`, `tensor.rs`,
//!    `util/{simd,arena,par}.rs`). The lock-poisoning idiom
//!    (`.lock().unwrap()` etc.) is allowed by default — a poisoned lock
//!    means a sibling thread already panicked, and propagating beats
//!    limping on with torn state. Escape hatch:
//!    `// audit: allow(unwrap): reason`.
//! 5. **unwind-safety** — every `catch_unwind` / `AssertUnwindSafe` site
//!    carries an `// unwind-safety:` comment (same line or the
//!    contiguous comment block above) arguing why state observable
//!    after the unwind is consistent. `AssertUnwindSafe` is a promise
//!    the compiler cannot check — a supervisor that resumes over
//!    half-mutated shared state turns one crash into silent corruption,
//!    so the argument must be written down where it can be reviewed.
//!
//! Plus a one-shot workspace check: `rust/src/lib.rs` must carry
//! `#![deny(unsafe_op_in_unsafe_fn)]` (**deny-attr**).
//!
//! Everything inside `#[cfg(test)] mod` blocks is exempt from all rules.

use crate::scan::Source;

#[derive(Debug)]
pub struct Violation {
    pub path: String,
    pub line: usize,
    pub rule: &'static str,
    pub msg: String,
}

impl std::fmt::Display for Violation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.path, self.line, self.rule, self.msg)
    }
}

const ORDERING_VARIANTS: [&str; 5] = ["Relaxed", "Acquire", "Release", "AcqRel", "SeqCst"];
const BLOCKING_CALLS: [&str; 9] = [
    ".send(",
    ".try_send(",
    ".execute(",
    "export_seq(",
    "import_seq(",
    ".probe(",
    ".publish(",
    ".spill(",
    ".page_in(",
];
const GUARD_CALLS: [&str; 4] = [".lock()", ".read()", ".write()", ".layer("];
const POISON_IDIOMS: [&str; 4] = [".lock()", ".read()", ".write()", ".into_inner()"];

/// Directories whose files are subject to the lock-across rule.
fn in_guarded_dirs(path: &str) -> bool {
    ["coordinator/", "kvcache/", "serve/"].iter().any(|d| path.contains(d))
}

/// Files subject to the unwrap/expect ban.
fn in_hot_path(path: &str) -> bool {
    in_guarded_dirs(path)
        || path.ends_with("tensor.rs")
        || path.ends_with("util/simd.rs")
        || path.ends_with("util/arena.rs")
        || path.ends_with("util/par.rs")
}

pub fn audit_source(src: &Source) -> Vec<Violation> {
    let mut out = Vec::new();
    check_unsafe(src, &mut out);
    check_unwind_safety(src, &mut out);
    check_ordering(src, &mut out);
    if in_guarded_dirs(&src.path) {
        check_lock_across(src, &mut out);
    }
    if in_hot_path(&src.path) {
        check_unwrap(src, &mut out);
    }
    out.sort_by_key(|v| v.line);
    out
}

/// Workspace-level check: the library crate root must deny implicit
/// unsafe inside `unsafe fn` bodies, so every dereference/call site gets
/// its own `unsafe {}` block and therefore its own SAFETY comment.
pub fn check_lib_attrs(src: &Source, out: &mut Vec<Violation>) {
    if src.path.ends_with("rust/src/lib.rs")
        && !src.masked.contains("#![deny(unsafe_op_in_unsafe_fn)]")
    {
        out.push(Violation {
            path: src.path.clone(),
            line: 1,
            rule: "deny-attr",
            msg: "crate root must carry #![deny(unsafe_op_in_unsafe_fn)]".into(),
        });
    }
}

/// Occurrences of `word` in `hay` at identifier boundaries.
fn word_positions(hay: &str, word: &str) -> Vec<usize> {
    let bytes = hay.as_bytes();
    let mut out = Vec::new();
    let mut from = 0;
    while let Some(rel) = hay[from..].find(word) {
        let pos = from + rel;
        from = pos + word.len();
        let before_ok = pos == 0 || !is_ident_byte(bytes[pos - 1]);
        let after = pos + word.len();
        let after_ok = after >= bytes.len() || !is_ident_byte(bytes[after]);
        if before_ok && after_ok {
            out.push(pos);
        }
    }
    out
}

fn is_ident_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

fn check_unsafe(src: &Source, out: &mut Vec<Violation>) {
    for pos in word_positions(&src.masked, "unsafe") {
        if src.in_test(pos) {
            continue;
        }
        let line = src.line_of(pos);
        let ok = src.annotated(line, |c| c.contains("SAFETY:") || c.contains("# Safety"));
        if !ok {
            out.push(Violation {
                path: src.path.clone(),
                line,
                rule: "unsafe-safety",
                msg: "`unsafe` without a `// SAFETY:` comment on the site or the \
                      comment block above it"
                    .into(),
            });
        }
    }
}

fn check_unwind_safety(src: &Source, out: &mut Vec<Violation>) {
    for word in ["catch_unwind", "AssertUnwindSafe"] {
        for pos in word_positions(&src.masked, word) {
            if src.in_test(pos) {
                continue;
            }
            let line = src.line_of(pos);
            let ok = src.annotated(line, |c| c.contains("unwind-safety:"));
            if !ok {
                out.push(Violation {
                    path: src.path.clone(),
                    line,
                    rule: "unwind-safety",
                    msg: format!(
                        "`{word}` without an `// unwind-safety:` comment arguing why \
                         state observable after the unwind is consistent"
                    ),
                });
            }
        }
    }
}

fn check_ordering(src: &Source, out: &mut Vec<Violation>) {
    // Coverage intervals: an `// ordering:` comment covers from its own
    // position to the end of its enclosing brace block.
    let intervals: Vec<(usize, usize, usize)> = src
        .comments
        .iter()
        .filter(|c| c.text.to_lowercase().contains("ordering:"))
        .map(|c| (c.pos, src.block_end(c.pos), c.line))
        .collect();

    let mut from = 0;
    while let Some(rel) = src.masked[from..].find("Ordering::") {
        let pos = from + rel;
        from = pos + "Ordering::".len();
        let rest = &src.masked[pos + "Ordering::".len()..];
        let variant: String = rest.chars().take_while(|ch| ch.is_ascii_alphanumeric()).collect();
        if !ORDERING_VARIANTS.contains(&variant.as_str()) {
            continue; // e.g. cmp::Ordering::Less
        }
        if src.in_test(pos) {
            continue;
        }
        let line = src.line_of(pos);
        let covered = intervals
            .iter()
            .any(|&(start, end, cline)| cline == line || (start < pos && pos < end));
        if !covered {
            out.push(Violation {
                path: src.path.clone(),
                line,
                rule: "ordering-note",
                msg: format!(
                    "Ordering::{variant} without an `// ordering:` justification \
                     (same line, or a standalone comment earlier in this block)"
                ),
            });
        }
    }
}

#[derive(Debug)]
struct Guard {
    name: String,
    depth: u32,
    line: usize,
}

fn check_lock_across(src: &Source, out: &mut Vec<Violation>) {
    let mut guards: Vec<Guard> = Vec::new();
    for line in 1..=src.num_lines() {
        let start = src.line_starts[line - 1];
        if src.in_test(start) {
            continue;
        }
        let content = src.masked_line(line).to_string();

        // 1. `drop(name)` kills the guard.
        for dpos in word_positions(&content, "drop") {
            let rest = &content[dpos + 4..];
            if let Some(inner) = rest.strip_prefix('(') {
                let name: String =
                    inner.chars().take_while(|ch| ch.is_ascii_alphanumeric() || *ch == '_').collect();
                guards.retain(|g| g.name != name);
            }
        }

        // 2. Blocking calls while a guard is live.
        for call in BLOCKING_CALLS {
            let mut cfrom = 0;
            while let Some(rel) = content[cfrom..].find(call) {
                let cpos = cfrom + rel;
                cfrom = cpos + call.len();
                // `export_seq(` / `import_seq(` must sit at an ident
                // boundary (a leading `.` in the needle handles the rest).
                if !call.starts_with('.') {
                    let b = content.as_bytes();
                    if cpos > 0 && is_ident_byte(b[cpos - 1]) {
                        continue;
                    }
                }
                let abs = start + cpos;
                let cur_depth = src.depth[abs];
                for g in &guards {
                    if cur_depth >= g.depth {
                        let allowed =
                            src.annotated(line, |c| c.contains("audit: allow(lock_across"));
                        if !allowed {
                            out.push(Violation {
                                path: src.path.clone(),
                                line,
                                rule: "lock-across",
                                msg: format!(
                                    "blocking call `{}` while guard `{}` (line {}) is live; \
                                     drop or scope the guard first",
                                    call.trim_start_matches('.').trim_end_matches('('),
                                    g.name,
                                    g.line
                                ),
                            });
                        }
                    }
                }
            }
        }

        // 3. New guard bindings: `let [mut] name = <expr with guard call>`.
        if let Some((name, let_pos)) = guard_binding(&content) {
            guards.retain(|g| g.name != name);
            guards.push(Guard { name, depth: src.depth[start + let_pos], line });
        }

        // 4. Guards whose block closed on this line die.
        let eol = src.line_starts.get(line).copied().unwrap_or(src.masked.len());
        let end_depth = src.depth[eol.min(src.depth.len() - 1)];
        guards.retain(|g| g.depth <= end_depth);
    }
}

/// If the masked line binds a named guard, return (name, byte pos of
/// `let` in the line). The RHS must *start* with the guard expression —
/// a `match`/`if` between `=` and the guard call means the guard is a
/// scrutinee temporary, which this pass does not track.
fn guard_binding(content: &str) -> Option<(String, usize)> {
    let let_pos = word_positions(content, "let").into_iter().next()?;
    let after_let = &content[let_pos + 3..];
    let mut rest = after_let.trim_start();
    if let Some(stripped) = rest.strip_prefix("mut ") {
        rest = stripped.trim_start();
    }
    let name: String = rest.chars().take_while(|ch| ch.is_ascii_alphanumeric() || *ch == '_').collect();
    if name.is_empty() {
        return None;
    }
    let after_name = rest[name.len()..].trim_start();
    let rhs = after_name.strip_prefix('=')?;
    let call_pos = GUARD_CALLS.iter().filter_map(|c| rhs.find(c)).min()?;
    let prefix = &rhs[..call_pos];
    for kw in ["match", "if", "loop", "while"] {
        if word_positions(prefix, kw).first().is_some() {
            return None;
        }
    }
    Some((name, let_pos))
}

fn check_unwrap(src: &Source, out: &mut Vec<Violation>) {
    for needle in [".unwrap()", ".expect("] {
        let mut from = 0;
        while let Some(rel) = src.masked[from..].find(needle) {
            let pos = from + rel;
            from = pos + needle.len();
            if src.in_test(pos) {
                continue;
            }
            // Poison-propagation idiom: `.lock().unwrap()` and friends.
            let before = src.masked[..pos].trim_end();
            if POISON_IDIOMS.iter().any(|idiom| before.ends_with(idiom)) {
                continue;
            }
            let line = src.line_of(pos);
            let allowed = src.annotated(line, |c| {
                c.contains("audit: allow(unwrap") || c.contains("audit: allow(expect")
            });
            if !allowed {
                out.push(Violation {
                    path: src.path.clone(),
                    line,
                    rule: "unwrap-hot",
                    msg: format!(
                        "`{}` in a hot-path module; return an error, or annotate \
                         `// audit: allow(unwrap): reason` if unreachable by construction",
                        needle.trim_start_matches('.').trim_end_matches('(')
                    ),
                });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use crate::selftest;

    #[test]
    fn fixtures_all_pass() {
        let failures = selftest::run_fixtures();
        assert!(failures.is_empty(), "self-test failures:\n{}", failures.join("\n"));
    }
}
