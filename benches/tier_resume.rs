//! Session-tier suspend/resume: TTFT of resuming a long conversation
//! vs. re-prefilling its full history.
//!
//! The scenario the tier exists for: a multi-turn client returns after
//! its request finished, holding a history of H tokens. Without the
//! tier the serving plane re-prefills all H tokens before the first new
//! token; with it, an exact-match resume rebuilds the sequence from the
//! suspended KV blocks and decodes immediately. Two history lengths
//! (8k and 32k on the long-context `bench-32k` preset) each run two
//! arms against a tier-enabled pool:
//!
//! - **resume**: same `session_id`, prompt == stored history — the tier
//!   restores the blocks (DRAM-resident here; spill-device timings live
//!   in the tier's own histograms) and the request goes straight to
//!   decode.
//! - **reprefill**: identical prompt, no session key — the full-history
//!   prefill every stateless server pays. The prefix cache is disabled
//!   so this arm is a true cold prefill.
//!
//! TTFT is measured submit → first streamed token. Writes
//! BENCH_tier.json (rows: history_tokens, both TTFTs, speedup, tier
//! counters). Full runs assert the acceptance contract: resume TTFT is
//! strictly below re-prefill TTFT at every length. Under `--quick` /
//! SCOUT_BENCH_SMOKE the bench shrinks to test-tiny lengths and only
//! exercises the paths (no assertions — n=1 timings are meaningless).

use std::time::{Duration, Instant};

use scoutattention::config::RunConfig;
use scoutattention::serve::{EnginePool, StreamEvent, Submission};
use scoutattention::util::bench::smoke;
use scoutattention::util::Json;

const WAIT: Duration = Duration::from_secs(900);

fn prompt(len: usize, salt: u32) -> Vec<u32> {
    (0..len as u32).map(|i| 1 + (i * 13 + salt * 5) % 255).collect()
}

struct Row {
    history_tokens: usize,
    ttft_resume_us: f64,
    ttft_reprefill_us: f64,
    resumed: u64,
    suspended: u64,
}

/// Submit one streaming request and return its TTFT in microseconds,
/// draining the stream to completion so phases never overlap.
fn timed_request(pool: &EnginePool, sub: Submission) -> f64 {
    let t0 = Instant::now();
    let h = pool.submit(sub.streaming());
    let mut ttft = None;
    loop {
        match h.recv_timeout(WAIT) {
            Some(StreamEvent::Token { .. }) => {
                ttft.get_or_insert_with(|| t0.elapsed().as_secs_f64() * 1e6);
            }
            Some(StreamEvent::Done(_)) => {
                return ttft.expect("request produced no token before Done")
            }
            Some(other) => panic!("unexpected event {other:?}"),
            None => panic!("stream stalled"),
        }
    }
}

fn run_length(preset: &str, history_tokens: usize, dram_blocks: usize) -> Row {
    let setup_new = 8usize;
    let new_tokens = 4usize;
    let mut cfg = RunConfig::for_preset(preset);
    cfg.server.replicas = 1;
    cfg.scout.tier_dram_blocks = dram_blocks;
    cfg.scout.prefix_cache_blocks = 0; // reprefill arm must be cold
    let pool = EnginePool::start(cfg).expect("pool start");

    // Establish the session: one finished turn whose history lands at
    // exactly `history_tokens` (prompt + generated).
    let p = prompt(history_tokens - setup_new, history_tokens as u32);
    let out = pool
        .submit(Submission::new(p.clone(), setup_new).with_session_id("bench"))
        .wait()
        .expect("setup turn");
    let mut history = p;
    history.extend_from_slice(&out.generated);
    assert_eq!(history.len(), history_tokens);

    // Arm order matters: the keyless re-prefill first (it never touches
    // the tier), then the resume (which consumes the session).
    let ttft_reprefill_us =
        timed_request(&pool, Submission::new(history.clone(), new_tokens));
    let ttft_resume_us =
        timed_request(&pool, Submission::new(history, new_tokens).with_session_id("bench"));

    let tier = pool.stats().get("tier").expect("tier stats").clone();
    let row = Row {
        history_tokens,
        ttft_resume_us,
        ttft_reprefill_us,
        resumed: tier.req_usize("resumed").unwrap_or(0) as u64,
        suspended: tier.req_usize("suspended").unwrap_or(0) as u64,
    };
    pool.shutdown().expect("shutdown");
    row
}

fn main() {
    let quick = smoke() || std::env::args().any(|a| a == "--quick");
    println!("tier_resume — session resume vs. full-history re-prefill TTFT");
    // Full mode: 8k and 32k histories on the long-context preset; quick
    // mode shrinks to test-tiny just to exercise suspend/resume e2e.
    let (preset, lengths, dram_blocks) = if quick {
        ("test-tiny", vec![64usize, 128], 64)
    } else {
        ("bench-32k", vec![8192usize, 32768], 4096)
    };

    let mut rows = Vec::new();
    for &h in &lengths {
        let r = run_length(preset, h, dram_blocks);
        println!(
            "history {:>6}  resume ttft {:>12.1} us  reprefill ttft {:>12.1} us  \
             ({:.1}x)  resumed {} suspended {}",
            r.history_tokens,
            r.ttft_resume_us,
            r.ttft_reprefill_us,
            r.ttft_reprefill_us / r.ttft_resume_us,
            r.resumed,
            r.suspended
        );
        rows.push(r);
    }

    let json_rows: Vec<Json> = rows
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("history_tokens", Json::num(r.history_tokens as f64)),
                ("ttft_resume_us", Json::num(r.ttft_resume_us)),
                ("ttft_reprefill_us", Json::num(r.ttft_reprefill_us)),
                ("speedup", Json::num(r.ttft_reprefill_us / r.ttft_resume_us)),
                ("tier_resumed", Json::num(r.resumed as f64)),
                ("tier_suspended", Json::num(r.suspended as f64)),
            ])
        })
        .collect();
    let json = Json::obj(vec![
        ("bench", Json::str("tier_resume")),
        ("quick", Json::Bool(quick)),
        ("preset", Json::str(preset)),
        ("rows", Json::Arr(json_rows)),
    ]);
    let path = std::env::var("SCOUT_BENCH_TIER_JSON")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| {
            std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_tier.json")
        });
    std::fs::write(&path, json.to_string()).expect("write bench json");
    println!("wrote tier resume rows to {}", path.display());

    for r in &rows {
        assert!(r.resumed >= 1, "the resume arm must actually resume");
    }
    if quick {
        println!("quick/smoke mode: skipping TTFT assertions");
        return;
    }
    for r in &rows {
        assert!(
            r.ttft_resume_us < r.ttft_reprefill_us,
            "resume must beat re-prefill at {} tokens \
             (resume {:.1}us, reprefill {:.1}us)",
            r.history_tokens,
            r.ttft_resume_us,
            r.ttft_reprefill_us
        );
    }
}
