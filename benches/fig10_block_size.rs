//! Fig. 10 — ScoutAttention throughput vs KV block size (16/32/64).
//!
//! Larger blocks shrink the GPU-resident digest cache (one kmin/kmax
//! pair per block), freeing HBM for more sequences -> larger feasible
//! batch -> higher throughput; selection granularity coarsens slightly.

use scoutattention::config::Method;
use scoutattention::sim::pipeline::{MethodSim, SynthWorkload};
use scoutattention::sim::timing::DeviceModel;

fn main() {
    let m = DeviceModel::default();
    let seq_len = 32768usize;
    println!("Fig 10 — Scout throughput vs block size (32k ctx)");
    println!("{:<8} {:>14} {:>10} {:>12}", "block", "digest MB/seq", "max batch", "tok/s");
    let mut prev = 0.0;
    for bs in [16usize, 32, 64] {
        // per-seq GPU bytes: resident budget KV + digests for all blocks
        let kv_tok = m.kv_bytes_per_token_layer;
        let budget_bytes = 2048.0 * kv_tok * m.n_layers as f64;
        let digest_bytes = (seq_len as f64 / bs as f64) * kv_tok * m.n_layers as f64;
        let per_seq = budget_bytes + digest_bytes;
        let max_batch = (m.kv_budget_bytes() / per_seq).floor() as usize;
        let mut w = SynthWorkload::paper_default(seq_len, max_batch);
        w.block_size = bs;
        let sim = MethodSim::new(Method::Scout, m.clone());
        let tps = sim.run(&w).throughput_tps();
        println!(
            "{bs:<8} {:>14.1} {max_batch:>10} {tps:>12.1}",
            digest_bytes / 1e6
        );
        assert!(tps >= prev * 0.98, "throughput should not drop with block size");
        prev = tps;
    }
    println!("\npaper: throughput grows with block size (digest cache shrinks)");
}
