//! Cross-request prefix reuse: TTFT under shared-system-prompt traffic.
//!
//! The scenario the prefix cache exists for: production chat traffic
//! re-prefills the same long system prompt on (almost) every request,
//! so prefill compute dominates time-to-first-token. Three arms replay
//! the same request count with 0%, 50%, and 90% of requests sharing a
//! long block-aligned system prefix (the rest are fully unique); the
//! pool is seeded by one warm-up request per arm. TTFT is measured per
//! request from submit to first streamed token.
//!
//! Writes BENCH_prefix.json (rows: share_pct, ttft mean/p50 us, pool
//! counters). Full runs assert the acceptance contract: TTFT drops
//! monotonically with the hit rate, and the 90%-hit arm lands at or
//! under 0.5x the 0%-hit arm. Under `--quick` / SCOUT_BENCH_SMOKE the
//! bench only exercises the paths on the tiny preset (n=1-scale timings
//! are meaningless, so no assertions).

use std::time::{Duration, Instant};

use scoutattention::config::RunConfig;
use scoutattention::serve::{EnginePool, StreamEvent, StreamHandle, Submission};
use scoutattention::util::bench::smoke;
use scoutattention::util::Json;

const WAIT: Duration = Duration::from_secs(300);

fn prompt(len: usize, salt: u32) -> Vec<u32> {
    (0..len as u32).map(|i| 1 + (i * 13 + salt * 5) % 255).collect()
}

struct ArmResult {
    share_pct: usize,
    requests: usize,
    ttft_mean_us: f64,
    ttft_p50_us: f64,
    hits: u64,
    published: u64,
}

fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    sorted[((sorted.len() - 1) as f64 * p) as usize]
}

/// Submit one streaming request and return its TTFT (submit -> first
/// token), draining the stream to completion before returning so arms
/// never overlap.
fn timed_request(pool: &EnginePool, prompt: Vec<u32>, new_tokens: usize) -> f64 {
    let t0 = Instant::now();
    let h = pool.submit(Submission::new(prompt, new_tokens).streaming());
    let mut ttft = None;
    loop {
        match h.recv_timeout(WAIT) {
            Some(StreamEvent::Token { .. }) => {
                ttft.get_or_insert_with(|| t0.elapsed().as_secs_f64() * 1e6);
            }
            Some(StreamEvent::Done(_)) => {
                return ttft.expect("request produced no token before Done")
            }
            Some(other) => panic!("unexpected event {other:?}"),
            None => panic!("stream stalled"),
        }
    }
}

fn drain(h: StreamHandle) {
    h.wait().expect("warm-up request completed");
}

#[allow(clippy::too_many_arguments)]
fn run_arm(
    preset: &str,
    share_pct: usize,
    n_requests: usize,
    prefix_blocks: usize,
    tail_len: usize,
    new_tokens: usize,
    cache_blocks: usize,
    prefill_chunk: usize,
) -> ArmResult {
    let mut cfg = RunConfig::for_preset(preset);
    cfg.server.replicas = 1;
    cfg.server.max_batch = 2;
    cfg.scout.prefill_chunk = prefill_chunk;
    cfg.scout.prefix_cache_blocks = cache_blocks;
    let pool = EnginePool::start(cfg).expect("pool start");
    let bs = pool.spec().block_size;
    let shared = prompt(prefix_blocks * bs, 7);

    // Seed the pool so the arm's hit fraction is realized from request
    // 0 (steady-state traffic, not a cold start).
    let mut warm = shared.clone();
    warm.extend(prompt(tail_len, 999));
    drain(pool.submit(Submission::new(warm, new_tokens)));

    let mut ttfts: Vec<f64> = Vec::new();
    for i in 0..n_requests {
        // First `share_pct`% of every 100-request stripe shares the
        // system prefix; deterministic and exact for n_requests <= 100.
        let hits_prefix = i * 100 < share_pct * n_requests;
        let p = if hits_prefix {
            let mut p = shared.clone();
            p.extend(prompt(tail_len, 100 + i as u32)); // unique tail
            p
        } else {
            prompt(prefix_blocks * bs + tail_len, 500 + i as u32)
        };
        ttfts.push(timed_request(&pool, p, new_tokens));
    }

    let stats = pool.stats();
    let pfx = stats.get("prefix").expect("prefix counters in stats");
    let result = ArmResult {
        share_pct,
        requests: n_requests,
        ttft_mean_us: ttfts.iter().sum::<f64>() / ttfts.len() as f64,
        ttft_p50_us: {
            let mut s = ttfts.clone();
            s.sort_by(|a, b| a.partial_cmp(b).unwrap());
            percentile(&s, 0.5)
        },
        hits: pfx.req_usize("hits").unwrap_or(0) as u64,
        published: pfx.req_usize("published").unwrap_or(0) as u64,
    };
    pool.shutdown().expect("shutdown");
    result
}

fn main() {
    let quick = smoke() || std::env::args().any(|a| a == "--quick");
    println!("prefix_reuse — TTFT vs shared-system-prompt hit rate");
    // Full mode: a ~1920-token shared system prompt on the serve-20m
    // preset (60 blocks of 32); quick mode shrinks to test-tiny just to
    // exercise probe/import/publish end to end.
    let (preset, n_requests, prefix_blocks, tail_len, cache_blocks, prefill_chunk) =
        if quick { ("test-tiny", 4, 8, 16, 64, 16) } else { ("serve-20m", 10, 60, 32, 1024, 256) };
    let new_tokens = 2;

    let mut results = Vec::new();
    for share_pct in [0usize, 50, 90] {
        let r = run_arm(
            preset,
            share_pct,
            n_requests,
            prefix_blocks,
            tail_len,
            new_tokens,
            cache_blocks,
            prefill_chunk,
        );
        println!(
            "share {:>3}%  requests {:>3}  ttft mean {:>10.1} us  p50 {:>10.1} us  \
             pool hits {:>4} published {:>4}",
            r.share_pct, r.requests, r.ttft_mean_us, r.ttft_p50_us, r.hits, r.published
        );
        results.push(r);
    }

    let rows: Vec<Json> = results
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("share_pct", Json::num(r.share_pct as f64)),
                ("requests", Json::num(r.requests as f64)),
                ("ttft_mean_us", Json::num(r.ttft_mean_us)),
                ("ttft_p50_us", Json::num(r.ttft_p50_us)),
                ("pool_hits", Json::num(r.hits as f64)),
                ("pool_published", Json::num(r.published as f64)),
            ])
        })
        .collect();
    let json = Json::obj(vec![
        ("bench", Json::str("prefix_reuse")),
        ("quick", Json::Bool(quick)),
        ("preset", Json::str(preset)),
        ("prefix_blocks", Json::num(prefix_blocks as f64)),
        ("rows", Json::Arr(rows)),
    ]);
    let path = std::env::var("SCOUT_BENCH_PREFIX_JSON")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| {
            std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_prefix.json")
        });
    std::fs::write(&path, json.to_string()).expect("write bench json");
    println!("wrote prefix reuse rows to {}", path.display());

    if quick {
        println!("quick/smoke mode: skipping TTFT assertions");
        return;
    }
    let (t0, t50, t90) =
        (results[0].ttft_mean_us, results[1].ttft_mean_us, results[2].ttft_mean_us);
    println!("ttft vs 0%-hit: 50% {:.2}x, 90% {:.2}x", t50 / t0, t90 / t0);
    assert!(results[1].hits > 0 && results[2].hits > 0, "hit arms must actually hit");
    assert!(
        t50 < t0 && t90 < t50,
        "TTFT must drop monotonically with the hit rate \
         (0%: {t0:.1}us, 50%: {t50:.1}us, 90%: {t90:.1}us)"
    );
    assert!(
        t90 <= 0.5 * t0,
        "90%-hit TTFT must be at most half the 0%-hit TTFT \
         ({t90:.1}us vs {t0:.1}us) — if this fails, imports are not skipping \
         prefill compute"
    );
}
