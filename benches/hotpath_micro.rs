//! Hot-path microbenchmarks (perf §L3), two planes:
//!
//! 1. **Kernel plane A/B** — every `util::simd` kernel measured at both
//!    levels (`portable` = the seed's scalar loops bit-for-bit, `avx2`
//!    when the machine has it). This is the PR-over-PR perf trajectory:
//!    rows land in `BENCH_hotpath.json` at the repo root (override with
//!    `SCOUT_BENCH_HOTPATH_JSON`), and on AVX2 hardware the run *asserts* the
//!    `matvec` / attend-blocks kernels hold a >= 2x speedup over the
//!    pre-kernel-plane scalar baseline.
//! 2. **Coordinator ops** — the decode-critical operations measured in
//!    situ on the live stack (interpreter backend out of the box;
//!    `make artifacts` + `--features pjrt` to measure the PJRT path).
//!
//! `make bench-baseline` runs this and `worker_group_scaling` and leaves
//! both JSON baselines at the repo root.

use scoutattention::config::RunConfig;
use scoutattention::engines::Partial;
use scoutattention::harness::Stack;
use scoutattention::kvcache::SeqKvCache;
use scoutattention::sparse::{score_blocks_native, select_topk};
use scoutattention::tensor::Tensor;
use scoutattention::util::bench::{bench, smoke, BenchResult};
use scoutattention::util::rope::RopeTable;
use scoutattention::util::simd::{self, Level};
use scoutattention::util::{Json, Rng64};

/// One machine-readable kernel measurement.
struct KernelRow {
    kernel: &'static str,
    level: &'static str,
    size: String,
    ns_per_iter: f64,
    gb_per_s: f64,
}

impl KernelRow {
    fn new(
        kernel: &'static str,
        level: Level,
        size: String,
        bytes: usize,
        r: &BenchResult,
    ) -> Self {
        let ns = r.mean_us * 1e3;
        let gbps = if r.mean_us > 0.0 { bytes as f64 / (r.mean_us * 1e-6) / 1e9 } else { 0.0 };
        Self { kernel, level: level.name(), size, ns_per_iter: ns, gb_per_s: gbps }
    }

    fn json(&self) -> Json {
        Json::obj(vec![
            ("kernel", Json::str(self.kernel)),
            ("level", Json::str(self.level)),
            ("size", Json::str(self.size.clone())),
            ("ns_per_iter", Json::num(self.ns_per_iter)),
            ("gb_per_s", Json::num(self.gb_per_s)),
        ])
    }
}

fn levels() -> Vec<Level> {
    if simd::avx2_available() {
        vec![Level::Portable, Level::Avx2]
    } else {
        vec![Level::Portable]
    }
}

fn rand_vec(rng: &mut Rng64, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.f32() - 0.5).collect()
}

/// ns/iter of `kernel` at `level` from the collected rows.
fn ns_of(rows: &[KernelRow], kernel: &str, level: Level) -> Option<f64> {
    rows.iter().find(|r| r.kernel == kernel && r.level == level.name()).map(|r| r.ns_per_iter)
}

fn kernel_plane(rows: &mut Vec<KernelRow>) {
    let mut rng = Rng64::new(42);

    // matvec: a QKV-projection-shaped tile (d_model 256 -> 256).
    let (m, n) = (256usize, 256usize);
    let x = rand_vec(&mut rng, m);
    let w = rand_vec(&mut rng, m * n);
    let mut out = vec![0.0f32; n];
    for lv in levels() {
        let r = bench("matvec", 50, 4000, || {
            simd::matvec_with(lv, &x, &w, n, &mut out);
            std::hint::black_box(&out);
        });
        println!("  [{}] {}", lv.name(), r.report());
        rows.push(KernelRow::new("matvec", lv, format!("{m}x{n}"), 4 * (m * n + m + n), &r));
    }

    // dot: lm-head-row-shaped.
    let nn = 4096usize;
    let a = rand_vec(&mut rng, nn);
    let b = rand_vec(&mut rng, nn);
    for lv in levels() {
        let r = bench("dot", 100, 20000, || {
            std::hint::black_box(simd::dot_with(lv, &a, &b));
        });
        println!("  [{}] {}", lv.name(), r.report());
        rows.push(KernelRow::new("dot", lv, format!("{nn}"), 8 * nn, &r));
    }

    // axpy: the matvec/partial-accumulate inner step.
    let mut y = vec![0.0f32; nn];
    for lv in levels() {
        let r = bench("axpy", 100, 20000, || {
            simd::axpy_with(lv, 0.37, &a, &mut y);
            std::hint::black_box(&y);
        });
        println!("  [{}] {}", lv.name(), r.report());
        rows.push(KernelRow::new("axpy", lv, format!("{nn}"), 12 * nn, &r));
    }

    // digest score: one Quest head-row.
    let dn = 1024usize;
    let lo = rand_vec(&mut rng, dn);
    let hi = rand_vec(&mut rng, dn);
    let qd = rand_vec(&mut rng, dn);
    for lv in levels() {
        let r = bench("digest_score", 100, 20000, || {
            std::hint::black_box(simd::digest_score_with(lv, &qd, &lo, &hi));
        });
        println!("  [{}] {}", lv.name(), r.report());
        rows.push(KernelRow::new("digest_score", lv, format!("{dn}"), 12 * dn, &r));
    }

    // attend_blocks kernel: 4 complete blocks x 16 tokens, GQA 8/2,
    // head_dim 64 — the CPU worker's per-job shape.
    let (hq, hkv, dd, bs, blocks) = (8usize, 2usize, 64usize, 16usize, 4usize);
    let wtok = hkv * dd;
    let q = rand_vec(&mut rng, hq * dd);
    let kslabs: Vec<Vec<f32>> = (0..blocks).map(|_| rand_vec(&mut rng, bs * wtok)).collect();
    let vslabs: Vec<Vec<f32>> = (0..blocks).map(|_| rand_vec(&mut rng, bs * wtok)).collect();
    let mut scores = vec![0.0f32; bs];
    let bytes = blocks * bs * wtok * 2 * 4;
    for lv in levels() {
        let r = bench("attend_blocks", 20, 2000, || {
            let mut p = Partial::empty(hq, dd);
            for (ks, vs) in kslabs.iter().zip(&vslabs) {
                simd::softmax_accum_with(
                    lv, &q, ks, vs, None, bs, hq, hkv, dd, 0.125, &mut p.acc, &mut p.m,
                    &mut p.l, &mut scores,
                );
            }
            std::hint::black_box(&p);
        });
        println!("  [{}] {}", lv.name(), r.report());
        rows.push(
            KernelRow::new("attend_blocks", lv, format!("{blocks}x{bs}x{hq}x{dd}"), bytes, &r),
        );
    }

    // RoPE: cached frequency table vs the seed's per-head powf loop.
    let (heads, d) = (8usize, 128usize);
    let table = RopeTable::new(10000.0, d);
    let mut xrope = rand_vec(&mut rng, heads * d);
    let r = bench("rope_table", 50, 10000, || {
        table.apply(&mut xrope, heads, d, 1234);
        std::hint::black_box(&xrope);
    });
    println!("  [table]    {}", r.report());
    let rope_bytes = 8 * heads * d;
    rows.push(KernelRow::new("rope_table", simd::level(), format!("{heads}x{d}"), rope_bytes, &r));
    let theta: f64 = 10000.0;
    let r = bench("rope_powf (seed)", 50, 10000, || {
        let half = d / 2;
        for head in 0..heads {
            let row = &mut xrope[head * d..(head + 1) * d];
            for i in 0..half {
                let freq = theta.powf(-(i as f64) / half as f64);
                let ang = 1234f64 * freq;
                let (sin, cos) = (ang.sin() as f32, ang.cos() as f32);
                let (x1, x2) = (row[i], row[i + half]);
                row[i] = x1 * cos - x2 * sin;
                row[i + half] = x1 * sin + x2 * cos;
            }
        }
        std::hint::black_box(&xrope);
    });
    println!("  [powf]     {}", r.report());
    rows.push(KernelRow::new("rope_powf", simd::level(), format!("{heads}x{d}"), rope_bytes, &r));
}

fn main() -> scoutattention::Result<()> {
    println!("kernel plane (simd level: {}):", simd::level().name());
    let mut rows: Vec<KernelRow> = Vec::new();
    kernel_plane(&mut rows);

    let cfg = RunConfig::for_preset("test-tiny");
    let stack = Stack::load(&cfg)?;
    let spec = stack.gpu.spec.clone();
    stack.rt.warmup()?;

    // populated cache
    let mut cache = SeqKvCache::new(&spec);
    let mut rng = Rng64::new(1);
    let w = spec.n_kv_heads * spec.head_dim;
    for _ in 0..spec.max_seq - 8 {
        for l in 0..spec.n_layers {
            let k: Vec<f32> = (0..w).map(|_| rng.f32() - 0.5).collect();
            let v: Vec<f32> = (0..w).map(|_| rng.f32() - 0.5).collect();
            cache.append_layer(l, &k, &v);
        }
        cache.advance();
    }
    let hq = spec.n_q_heads;
    let d = spec.head_dim;
    let q: Vec<f32> = (0..hq * d).map(|_| rng.f32() - 0.5).collect();
    let full = cache.full_blocks();

    let mut results = Vec::new();
    results.push(bench("score_blocks_native (per seq/layer)", 20, 2000, || {
        std::hint::black_box(score_blocks_native(
            &q, &cache.digests, 0, full, hq, spec.n_kv_heads, d,
        ));
    }));
    let scores = score_blocks_native(&q, &cache.digests, 0, full, hq, spec.n_kv_heads, d);
    results.push(bench("select_topk", 20, 5000, || {
        std::hint::black_box(select_topk(&scores, spec.k_blocks, &[0, full - 1]));
    }));
    let kb = spec.k_blocks;
    let bs = spec.block_size;
    let blk_w = bs * w;
    let blocks: Vec<usize> = (0..kb.min(full)).collect();
    let mut kbuf = vec![0.0f32; kb * blk_w];
    let mut vbuf = vec![0.0f32; kb * blk_w];
    let mut mbuf = vec![0.0f32; kb * bs];
    results.push(bench("gather_blocks (per seq/layer)", 20, 2000, || {
        cache.gather_blocks(0, &blocks, kb, &mut kbuf, &mut vbuf, &mut mbuf);
    }));
    results.push(bench("cpu attend_blocks x4 (worker job)", 10, 500, || {
        std::hint::black_box(stack.native.attend_blocks(
            &q,
            &cache.layer_slabs(0),
            &blocks[..4.min(blocks.len())],
        ));
    }));
    let mut pa = Partial::empty(hq, d);
    pa.update_token(0, 0.3, &vec![1.0; d]);
    let mut pb = Partial::empty(hq, d);
    pb.update_token(0, -0.1, &vec![0.5; d]);
    results.push(bench("partial merge (per seq/layer)", 100, 20000, || {
        let mut x = pa.clone();
        x.merge(&pb);
        std::hint::black_box(x);
    }));

    // XLA calls (the "GPU")
    let b = spec.batch;
    let qx = Tensor::zeros(&[b, hq, d]);
    let kx = Tensor::zeros(&[b, kb, bs, spec.n_kv_heads, d]);
    let vx = Tensor::zeros(&[b, kb, bs, spec.n_kv_heads, d]);
    let mx = Tensor::full(&[b, kb, bs], 1.0);
    results.push(bench("xla sparse_attn (batch tile)", 5, 200, || {
        std::hint::black_box(stack.gpu.sparse_attn(&qx, &kx, &vx, &mx).unwrap());
    }));
    let x = Tensor::zeros(&[b, spec.d_model]);
    let pos: Vec<i32> = vec![64; b];
    results.push(bench("xla pre_attn (batch tile)", 5, 200, || {
        std::hint::black_box(stack.gpu.pre_attn(&x, 0, &pos).unwrap());
    }));
    results.push(bench("xla qpred (batch tile)", 5, 200, || {
        std::hint::black_box(stack.gpu.qpred(&x, 1, &pos).unwrap());
    }));
    results.push(bench("xla lm_head (batch tile)", 5, 200, || {
        std::hint::black_box(stack.gpu.lm_head(&x).unwrap());
    }));

    println!("\nhot-path microbenchmarks ({}):", spec.name);
    for r in &results {
        println!("  {}", r.report());
    }

    // Machine-readable baseline at the repo root.
    let json = Json::obj(vec![
        ("bench", Json::str("hotpath_micro")),
        ("simd_level", Json::str(simd::level().name())),
        ("smoke", Json::Bool(smoke())),
        ("rows", Json::Arr(rows.iter().map(|r| r.json()).collect())),
    ]);
    let path = std::env::var("SCOUT_BENCH_HOTPATH_JSON")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| {
            std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_hotpath.json")
        });
    std::fs::write(&path, json.to_string())?;
    println!("\nwrote {} kernel rows to {}", rows.len(), path.display());

    if smoke() {
        println!("smoke mode: skipping the kernel speedup assertions (n=1 timings)");
        return Ok(());
    }
    if simd::avx2_available() {
        for kernel in ["matvec", "attend_blocks"] {
            let p = ns_of(&rows, kernel, Level::Portable).expect("portable row");
            let v = ns_of(&rows, kernel, Level::Avx2).expect("avx2 row");
            let speedup = p / v;
            println!("{kernel}: portable {p:.0} ns -> avx2 {v:.0} ns ({speedup:.2}x)");
            assert!(
                speedup >= 2.0,
                "{kernel}: avx2 kernel must be >= 2x the scalar baseline, got {speedup:.2}x"
            );
        }
    } else {
        println!("no AVX2 on this machine: portable fallback selected; speedup gate skipped");
    }
    Ok(())
}
