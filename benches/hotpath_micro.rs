//! Hot-path microbenchmarks (perf §L3): the coordinator-side operations
//! that sit on the decode critical path, measured in isolation with the
//! in-tree bench harness. Runs on the interpreter backend out of the box
//! (`make artifacts` + `--features pjrt` to measure the PJRT path).

use scoutattention::config::RunConfig;
use scoutattention::engines::Partial;
use scoutattention::harness::Stack;
use scoutattention::kvcache::SeqKvCache;
use scoutattention::sparse::{score_blocks_native, select_topk};
use scoutattention::tensor::Tensor;
use scoutattention::util::bench::bench;
use scoutattention::util::Rng64;

fn main() -> scoutattention::Result<()> {
    let cfg = RunConfig::for_preset("test-tiny");
    let stack = Stack::load(&cfg)?;
    let spec = stack.gpu.spec.clone();
    stack.rt.warmup()?;

    // populated cache
    let mut cache = SeqKvCache::new(&spec);
    let mut rng = Rng64::new(1);
    let w = spec.n_kv_heads * spec.head_dim;
    for _ in 0..spec.max_seq - 8 {
        for l in 0..spec.n_layers {
            let k: Vec<f32> = (0..w).map(|_| rng.f32() - 0.5).collect();
            let v: Vec<f32> = (0..w).map(|_| rng.f32() - 0.5).collect();
            cache.append_layer(l, &k, &v);
        }
        cache.advance();
    }
    let hq = spec.n_q_heads;
    let d = spec.head_dim;
    let q: Vec<f32> = (0..hq * d).map(|_| rng.f32() - 0.5).collect();
    let full = cache.full_blocks();

    let mut results = Vec::new();
    results.push(bench("score_blocks_native (per seq/layer)", 20, 2000, || {
        std::hint::black_box(score_blocks_native(
            &q, &cache.digests, 0, full, hq, spec.n_kv_heads, d,
        ));
    }));
    let scores = score_blocks_native(&q, &cache.digests, 0, full, hq, spec.n_kv_heads, d);
    results.push(bench("select_topk", 20, 5000, || {
        std::hint::black_box(select_topk(&scores, spec.k_blocks, &[0, full - 1]));
    }));
    let kb = spec.k_blocks;
    let bs = spec.block_size;
    let blk_w = bs * w;
    let blocks: Vec<usize> = (0..kb.min(full)).collect();
    let mut kbuf = vec![0.0f32; kb * blk_w];
    let mut vbuf = vec![0.0f32; kb * blk_w];
    let mut mbuf = vec![0.0f32; kb * bs];
    results.push(bench("gather_blocks (per seq/layer)", 20, 2000, || {
        cache.gather_blocks(0, &blocks, kb, &mut kbuf, &mut vbuf, &mut mbuf);
    }));
    results.push(bench("cpu attend_blocks x4 (worker job)", 10, 500, || {
        std::hint::black_box(stack.native.attend_blocks(&q, &cache, 0, &blocks[..4.min(blocks.len())]));
    }));
    let mut pa = Partial::empty(hq, d);
    pa.update_token(0, 0.3, &vec![1.0; d]);
    let mut pb = Partial::empty(hq, d);
    pb.update_token(0, -0.1, &vec![0.5; d]);
    results.push(bench("partial merge (per seq/layer)", 100, 20000, || {
        let mut x = pa.clone();
        x.merge(&pb);
        std::hint::black_box(x);
    }));

    // XLA calls (the "GPU")
    let b = spec.batch;
    let qx = Tensor::zeros(&[b, hq, d]);
    let kx = Tensor::zeros(&[b, kb, bs, spec.n_kv_heads, d]);
    let vx = Tensor::zeros(&[b, kb, bs, spec.n_kv_heads, d]);
    let mx = Tensor::full(&[b, kb, bs], 1.0);
    results.push(bench("xla sparse_attn (batch tile)", 5, 200, || {
        std::hint::black_box(stack.gpu.sparse_attn(&qx, &kx, &vx, &mx).unwrap());
    }));
    let x = Tensor::zeros(&[b, spec.d_model]);
    let pos: Vec<i32> = vec![64; b];
    results.push(bench("xla pre_attn (batch tile)", 5, 200, || {
        std::hint::black_box(stack.gpu.pre_attn(&x, 0, &pos).unwrap());
    }));
    results.push(bench("xla qpred (batch tile)", 5, 200, || {
        std::hint::black_box(stack.gpu.qpred(&x, 1, &pos).unwrap());
    }));
    results.push(bench("xla lm_head (batch tile)", 5, 200, || {
        std::hint::black_box(stack.gpu.lm_head(&x).unwrap());
    }));

    println!("\nhot-path microbenchmarks ({}):", spec.name);
    for r in &results {
        println!("  {}", r.report());
    }
    Ok(())
}
