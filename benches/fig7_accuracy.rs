//! Fig. 7 — accuracy of the four methods across sparse budgets.
//!
//! LongBench substitution (DESIGN.md §2): token agreement with the dense
//! FullKV oracle on identical streams + needle-block selection recall.
//! Budgets swept by re-running Scout/baselines with different k_blocks
//! capacities on the test-tiny stack (budget = k_blocks * block_size).

use scoutattention::config::{Method, RunConfig};
use scoutattention::harness::{self, Stack};
use scoutattention::kvcache::SeqKvCache;
use scoutattention::model::PROXY_MODELS;
use scoutattention::sparse::{score_blocks_native, select_topk};
use scoutattention::util::Rng64;
use scoutattention::workload::plant_needle;
use scoutattention::workload::{LengthMix, WorkloadGen};

fn main() -> scoutattention::Result<()> {
    let cfg = RunConfig::for_preset("test-tiny");
    let stack = Stack::load(&cfg)?;
    let spec = stack.gpu.spec.clone();
    let reqs = WorkloadGen::new(11, spec.vocab, LengthMix::Fixed(spec.block_size * 12), 16).take(3);
    let oracle = harness::run_method(&stack, Method::FullKv, reqs.clone(), 10_000, None)?;

    println!("Fig 7 — accuracy proxy: token agreement with FullKV (test-tiny)");
    println!("budget = {} tokens ({} blocks)", spec.k_blocks * spec.block_size, spec.k_blocks);
    println!("{:<15} {:>10}", "method", "agree%");
    let mut scout_agree = 0.0;
    for m in [Method::Scout, Method::Infinigen, Method::Hgca] {
        let run = harness::run_method(&stack, m, reqs.clone(), 10_000, None)?;
        let a = harness::token_agreement(&run, &oracle);
        if m == Method::Scout {
            scout_agree = a;
        }
        println!("{:<15} {:>9.1}%", m.label(), a * 100.0);
    }

    // Head-wise offload arm: the same stream with the offload machinery
    // at per-head-group granularity (scout.head_groups = n_kv_heads).
    // Same weights (preset + seed), so the FullKV oracle carries over;
    // the HeadInfer-style granularity must not cost meaningful accuracy
    // vs per-layer Scout (2.4% bound, matching the paper's Fig. 7 gap).
    let mut hcfg = cfg.clone();
    hcfg.scout.head_groups = spec.n_kv_heads;
    let hstack = Stack::load(&hcfg)?;
    let hrun = harness::run_method(&hstack, Method::Scout, reqs.clone(), 10_000, None)?;
    let h_agree = harness::token_agreement(&hrun, &oracle);
    println!(
        "{:<15} {:>9.1}%   (head_groups = {})",
        "scout-headwise",
        h_agree * 100.0,
        spec.n_kv_heads
    );
    assert!(
        h_agree >= scout_agree - 0.024,
        "head-wise Scout agreement {:.3} fell more than 2.4% below per-layer Scout {:.3}",
        h_agree,
        scout_agree
    );

    // Needle-retrieval accuracy vs budget: does top-k keep the planted
    // block? (mechanism behind LongBench retrieval scores)
    println!("\nneedle-block selection recall vs budget (native, qwen3-8b-proxy)");
    println!("{:>8} {:>16} {:>16}", "budget", "scout top-k", "window-only");
    let pspec = PROXY_MODELS[0].1();
    let mut rng = Rng64::new(5);
    for budget_blocks in [4usize, 8, 16] {
        let mut hits_topk = 0;
        let mut hits_window = 0;
        let trials = 40;
        for t in 0..trials {
            let mut cache = SeqKvCache::new(&pspec);
            let w = pspec.n_kv_heads * pspec.head_dim;
            for _ in 0..pspec.max_seq - 1 {
                for l in 0..pspec.n_layers {
                    let k: Vec<f32> = (0..w).map(|_| rng.f32() - 0.5).collect();
                    let v: Vec<f32> = (0..w).map(|_| rng.f32() - 0.5).collect();
                    cache.append_layer(l, &k, &v);
                }
                cache.advance();
            }
            let full = cache.full_blocks();
            let needle = rng.range(1, full - 2);
            let dir = plant_needle(&mut cache, &pspec, needle, 16.0, 100 + t as u64);
            // query aligned with the needle direction
            let g = pspec.n_q_heads / pspec.n_kv_heads;
            let d = pspec.head_dim;
            let mut q = vec![0.0f32; pspec.n_q_heads * d];
            for h in 0..pspec.n_q_heads {
                q[h * d..(h + 1) * d].copy_from_slice(&dir[(h / g) * d..(h / g + 1) * d]);
            }
            let scores = score_blocks_native(
                &q, &cache.digests, 0, full, pspec.n_q_heads, pspec.n_kv_heads, d,
            );
            let sel = select_topk(&scores, budget_blocks, &[0]);
            if sel.blocks.contains(&needle) {
                hits_topk += 1;
            }
            // window-only baseline: sink + most recent blocks
            let window: Vec<usize> = (0..budget_blocks)
                .map(|i| if i == 0 { 0 } else { full - i })
                .collect();
            if window.contains(&needle) {
                hits_window += 1;
            }
        }
        println!(
            "{:>8} {:>15.0}% {:>15.0}%",
            budget_blocks * pspec.block_size,
            hits_topk as f64 / trials as f64 * 100.0,
            hits_window as f64 / trials as f64 * 100.0
        );
        assert!(hits_topk > hits_window, "digest top-k must beat a static window");
    }
    println!("\npaper: Scout within 2.1-2.5% of FullKV; selection quality is the mechanism");
    Ok(())
}
