//! Fig. 6 — CPU compute ratio across decode steps, measured on the real
//! artifact stack (numerics plane): 6a without periodic recall (drift
//! accumulates), 6b with profiled per-layer intervals at beta = 12%.
//! Runs on the interpreter backend out of the box (test-tiny preset).

use scoutattention::config::{Method, RecallPolicy, RunConfig};
use scoutattention::coordinator::RecallController;
use scoutattention::harness::{self, Stack};
use scoutattention::workload::{LengthMix, WorkloadGen};

fn main() -> scoutattention::Result<()> {
    let cfg = RunConfig::for_preset("test-tiny");
    let stack = Stack::load(&cfg)?;
    let spec = stack.gpu.spec.clone();
    let steps = 48usize;
    let prompt = spec.max_seq - steps - 2;
    let mk = |seed| {
        WorkloadGen::new(seed, spec.vocab, LengthMix::Fixed(prompt), steps).take(2)
    };

    // 6a: no recall
    let mut cfg_a = cfg.clone();
    cfg_a.scout.recall = RecallPolicy::Disabled;
    let stack_a = Stack { cfg: cfg_a, rt: stack.rt.clone(), gpu: stack.gpu.clone(), native: stack.native.clone() };
    let run_a = harness::run_method(&stack_a, Method::Scout, mk(1), 10_000, None)?;

    // profile intervals and run 6b
    let series = run_a.cpu_ratio_series(spec.n_layers);
    let rc = RecallController::new(&cfg.scout, spec.n_layers, Some(&series));
    let run_b = harness::run_method(&stack, Method::Scout, mk(1), 10_000, Some(&series))?;

    println!("Fig 6 — CPU compute ratio per decode step (test-tiny, 2 seqs)");
    println!("{:>5} {:>14} {:>14}", "step", "6a no-recall", "6b periodic");
    for i in (0..run_a.stats.len().min(run_b.stats.len())).step_by(4) {
        println!(
            "{i:>5} {:>13.1}% {:>13.1}%",
            run_a.stats[i].cpu_ratio() * 100.0,
            run_b.stats[i].cpu_ratio() * 100.0
        );
    }
    println!(
        "\nmean ratio: {:.1}% -> {:.1}%  (paper: drifts upward -> 8.2%)",
        run_a.mean_cpu_ratio() * 100.0,
        run_b.mean_cpu_ratio() * 100.0
    );
    println!(
        "profiled intervals {:?} (mean {:.1}; paper mean 8.7)",
        rc.intervals,
        rc.mean_interval()
    );
    assert!(run_b.mean_cpu_ratio() <= run_a.mean_cpu_ratio() + 1e-9);
    Ok(())
}
