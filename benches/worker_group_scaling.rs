//! Worker-group scaling: decode throughput of the Scout scheduler on
//! the interpreter backend as the CPU plane widens, sweeping worker
//! groups × threads-per-group × batch size.
//!
//! Each arm builds a fresh stack on a CPU-heavy shape (tight resident
//! budget, wide top-k ⇒ most selected blocks land on the CPU side),
//! prefills the batch, then times the decode loop only. One JSON row
//! per arm (decode steps/s) feeds the perf trajectory.
//!
//! The load-bearing comparison: a single shared 1-thread group (the
//! pre-sharding pool shape) vs one group per sequence — per-sequence
//! groups must scale decode throughput on a multi-sequence batch.

use std::sync::Arc;

use scoutattention::config::{RecallPolicy, ScoutConfig};
use scoutattention::coordinator::{Batch, DecodeScheduler, RecallController, ScoutScheduler};
use scoutattention::engines::{GpuEngine, NativeEngine};
use scoutattention::model::spec::builtin_preset;
use scoutattention::model::{ModelSpec, Weights};
use scoutattention::runtime::Runtime;
use scoutattention::util::bench::smoke;
use scoutattention::util::Json;
use scoutattention::workload::{LengthMix, WorkloadGen};

const DECODE_TOKENS: usize = 16;
const PROMPT_BLOCKS: usize = 32;

fn bench_spec(batch: usize) -> ModelSpec {
    let mut s = builtin_preset("test-tiny").unwrap();
    s.name = format!("wg-scaling-b{batch}");
    s.n_layers = 4;
    s.d_model = 64;
    s.n_q_heads = 4;
    s.n_kv_heads = 2;
    s.head_dim = 16;
    s.d_ff = 128;
    s.vocab = 64;
    s.max_seq = 768;
    s.block_size = 16;
    // Wide top-k + tiny resident budget (below): most selected blocks
    // miss the GPU pool, so the CPU plane carries the step.
    s.k_blocks = 16;
    s.batch = batch;
    s
}

/// One arm: build stack, prefill `batch` sequences, time decode only.
/// Returns decode steps per second.
fn run_arm(batch: usize, worker_groups: usize, threads_per_group: usize) -> f64 {
    let spec = bench_spec(batch);
    let rt = Arc::new(Runtime::for_spec(&spec).expect("synthesized runtime"));
    let weights = Weights::generate(&spec, 7, 1.0);
    let gpu = Arc::new(GpuEngine::new(rt, weights.clone()).expect("gpu engine"));
    let native = Arc::new(NativeEngine::new(spec.clone(), weights));
    let cfg = ScoutConfig {
        recall: RecallPolicy::Fixed { interval: 4 },
        worker_groups,
        threads_per_group,
        ..ScoutConfig::default()
    };
    let recall = RecallController::new(&cfg, spec.n_layers, None);
    let mut sched = ScoutScheduler::new(gpu, native, cfg, recall);

    let budget_blocks = 2; // resident capacity per (seq, layer)
    let mut batch_q = Batch::new(spec.clone(), budget_blocks, batch);
    let mut gen = WorkloadGen::new(
        11,
        spec.vocab,
        LengthMix::Fixed(spec.block_size * PROMPT_BLOCKS),
        DECODE_TOKENS,
    );
    for req in gen.take(batch) {
        sched.admit(&mut batch_q, &req).expect("prefill");
    }

    let t0 = std::time::Instant::now();
    let mut steps = 0usize;
    let cap = if smoke() { 2 } else { DECODE_TOKENS + 4 };
    while batch_q.live() > 0 && steps < cap {
        sched.step(&mut batch_q).expect("decode step");
        batch_q.reap();
        steps += 1;
    }
    steps as f64 / t0.elapsed().as_secs_f64().max(1e-9)
}

fn main() {
    println!("worker_group_scaling — decode steps/s on the interpreter backend");
    // (batch, worker_groups [0 = one per slot], threads_per_group)
    let arms: &[(usize, usize, usize)] = &[
        (1, 1, 1),
        (4, 1, 1), // single shared 1-thread group: the scaling baseline
        (4, 0, 1), // one group per sequence = 4 worker threads
        (4, 0, 2), // two threads per group = 8 worker threads
    ];
    let mut single_group = 0.0;
    let mut per_seq = 0.0;
    let mut rows: Vec<Json> = Vec::new();
    for &(batch, groups, tpg) in arms {
        let sps = run_arm(batch, groups, tpg);
        let eff_groups = if groups == 0 { batch } else { groups };
        println!(
            "{{\"bench\":\"worker_group_scaling\",\"batch\":{batch},\
             \"worker_groups\":{eff_groups},\"threads_per_group\":{tpg},\
             \"total_threads\":{},\"decode_steps_per_s\":{sps:.3}}}",
            eff_groups * tpg
        );
        rows.push(Json::obj(vec![
            ("batch", Json::num(batch as f64)),
            ("worker_groups", Json::num(eff_groups as f64)),
            ("threads_per_group", Json::num(tpg as f64)),
            ("total_threads", Json::num((eff_groups * tpg) as f64)),
            ("decode_steps_per_s", Json::num(sps)),
        ]));
        if (batch, groups, tpg) == (4, 1, 1) {
            single_group = sps;
        }
        if (batch, groups, tpg) == (4, 0, 1) {
            per_seq = sps;
        }
    }
    // Machine-readable baseline at the repo root.
    let json = Json::obj(vec![
        ("bench", Json::str("worker_group_scaling")),
        ("smoke", Json::Bool(smoke())),
        ("rows", Json::Arr(rows)),
    ]);
    let path = std::env::var("SCOUT_BENCH_WG_JSON")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| {
            std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_worker_groups.json")
        });
    std::fs::write(&path, json.to_string()).expect("write bench json");
    println!("wrote scaling rows to {}", path.display());
    if smoke() {
        println!("smoke mode: skipping the scaling assertion (n=1 timings)");
        return;
    }
    println!(
        "batch 4: single shared thread {single_group:.1} steps/s -> per-seq groups {per_seq:.1} steps/s ({:.2}x)",
        per_seq / single_group
    );
    assert!(
        per_seq > single_group * 1.05,
        "per-sequence worker groups must beat a single shared 1-thread group \
         on a multi-sequence batch: {per_seq:.1} vs {single_group:.1} steps/s"
    );
}
