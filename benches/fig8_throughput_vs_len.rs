//! Fig. 8 — decode throughput vs input length (8k/16k/32k/64k, batch 40).
//!
//! Shape checks from the paper: ScoutAttention wins everywhere; FullKV
//! degrades sharply with length (memory-capacity waves) and is *faster*
//! than both offloading baselines at 8k; Scout reaches ~5.1x FullKV and
//! ~2.1x the best offloading method at 64k.

use scoutattention::config::Method;
use scoutattention::sim::pipeline::{MethodSim, SynthWorkload};
use scoutattention::sim::timing::DeviceModel;

fn run(m: Method, seq_len: usize) -> f64 {
    let mut sim = MethodSim::new(m, DeviceModel::default());
    if m != Method::Scout {
        sim.periodic_recall = false;
    }
    sim.run(&SynthWorkload::paper_default(seq_len, 40)).throughput_tps()
}

fn main() {
    println!("Fig 8 — decode throughput (tok/s) vs input length, batch 40");
    println!("{:<9} {:>10} {:>10} {:>10} {:>10} {:>8} {:>8}",
        "len", "FullKV", "InfiniGen", "HGCA", "Scout", "x Full", "x best");
    for len in [8192, 16384, 32768, 65536] {
        let f = run(Method::FullKv, len);
        let i = run(Method::Infinigen, len);
        let h = run(Method::Hgca, len);
        let s = run(Method::Scout, len);
        let best_off = i.max(h);
        println!(
            "{:<9} {f:>10.1} {i:>10.1} {h:>10.1} {s:>10.1} {:>7.2}x {:>7.2}x",
            format!("{}k", len / 1024), s / f, s / best_off
        );
        assert!(s > f && s > i && s > h, "scout must win at {len}");
        if len == 8192 {
            assert!(f > i && f > h, "paper: baselines below FullKV at 8k");
        }
        if len == 65536 {
            assert!(s / f > 3.0, "scout vs FullKV at 64k: {:.2}x (paper 5.1x)", s / f);
            assert!(s / best_off > 1.4, "scout vs best offloading: {:.2}x (paper 2.1x)", s / best_off);
        }
    }
}
