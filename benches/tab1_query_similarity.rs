//! Table 1 — cosine similarity between the layer-ahead predicted query
//! (W_Q^{i+1} X^i) and the real query (W_Q^{i+1} X^{i+1}) across the
//! proxy model zoo. Paper reports 0.93-0.97 on the real checkpoints.

fn main() -> scoutattention::Result<()> {
    scoutattention::studies::tab1_query_similarity(0xC0FFEE, &mut std::io::stdout())
}
