//! Fig. 2 — effective GPU<->CPU I/O bandwidth vs transfer granularity.
//!
//! Regenerates the paper's curve from the calibrated device model and
//! checks its two anchors: ~0.8 GB/s at 4 KB (per-token KV messages) and
//! ~15 GB/s at 128 KB (32-token pages).

use scoutattention::sim::timing::DeviceModel;

fn main() {
    let m = DeviceModel::default();
    println!("Fig 2 — PCIe effective bandwidth vs message size");
    println!("{:>12} {:>14}", "msg size", "eff. GB/s");
    for kb in [1, 4, 16, 32, 64, 128, 256, 1024, 4096, 16384] {
        let bytes = kb as f64 * 1024.0;
        let bw = m.pcie_effective_bw(bytes) * 1e6 / 1e9;
        println!("{:>9} KB {:>14.2}", kb, bw);
    }
    let bw4k = m.pcie_effective_bw(4096.0) * 1e6 / 1e9;
    let bw128k = m.pcie_effective_bw(131072.0) * 1e6 / 1e9;
    println!("\nanchors: 4KB -> {bw4k:.2} GB/s (paper ~0.8), 128KB -> {bw128k:.2} GB/s (paper ~15)");
    println!("HBM for comparison: {:.1} TB/s", m.hbm_bw * 1e6 / 1e12);
    assert!((0.5..1.2).contains(&bw4k) && (10.0..18.0).contains(&bw128k));
}
