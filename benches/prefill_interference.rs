//! Prefill interference on decode inter-token latency: inline vs
//! chunked vs disaggregated.
//!
//! The scenario the refactor exists for: live streaming decodes share a
//! pool with one long-prompt admission. Four arms measure the decode
//! streams' inter-token gaps while the admission runs:
//!
//! - `baseline`  — no concurrent prefill (the latency floor);
//! - `inline`    — `prefill_chunk` >= prompt: the seed's whole-prompt
//!   prefill between decode steps (stalls every co-batched decode);
//! - `chunked`   — small chunks interleaved on one mixed replica;
//! - `disagg`    — 1 prefill + 2 decode replicas: the admission
//!   prefills elsewhere and arrives via KV handoff.
//!
//! Writes BENCH_prefill.json (rows: arm, gap p50/p99 us, ratio to
//! baseline). Full runs assert the acceptance contract: chunked and
//! disaggregated keep decode p99 within 2x of the no-prefill baseline,
//! while inline measurably does not. Under `--quick` /
//! SCOUT_BENCH_SMOKE the bench only exercises the paths (n=1-scale
//! timings are meaningless).

use std::time::{Duration, Instant};

use scoutattention::config::{ReplicaRole, RunConfig};
use scoutattention::serve::{EnginePool, StreamEvent, StreamHandle, Submission};
use scoutattention::util::bench::smoke;
use scoutattention::util::Json;

const WAIT: Duration = Duration::from_secs(300);

fn prompt(len: usize, salt: u32) -> Vec<u32> {
    (0..len as u32).map(|i| 1 + (i * 13 + salt * 5) % 255).collect()
}

struct Arm {
    name: &'static str,
    replicas: usize,
    roles: Vec<ReplicaRole>,
    prefill_chunk: usize,
    with_prefill: bool,
}

struct ArmResult {
    name: &'static str,
    gaps: usize,
    p50_us: f64,
    p99_us: f64,
}

fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    sorted[((sorted.len() - 1) as f64 * p) as usize]
}

/// Wait for the first Token event (the stream is live in the batch).
fn first_token(h: &StreamHandle) {
    loop {
        match h.recv_timeout(WAIT) {
            Some(StreamEvent::Token { .. }) => return,
            Some(StreamEvent::Done(_)) => panic!("decode stream finished before measurement"),
            Some(other) => panic!("unexpected event {other:?}"),
            None => panic!("decode stream stalled"),
        }
    }
}

/// Drain a decode stream to completion, stamping each token's arrival.
fn collect_token_times(h: &StreamHandle) -> Vec<Instant> {
    let mut times = Vec::new();
    loop {
        match h.recv_timeout(WAIT) {
            Some(StreamEvent::Token { .. }) => times.push(Instant::now()),
            Some(StreamEvent::Done(_)) => return times,
            Some(other) => panic!("unexpected event {other:?}"),
            None => panic!("decode stream stalled"),
        }
    }
}

fn run_arm(arm: &Arm, decode_tokens: usize, prefill_len: usize) -> ArmResult {
    let mut cfg = RunConfig::for_preset("test-tiny");
    cfg.server.replicas = arm.replicas;
    cfg.server.roles = arm.roles.clone();
    cfg.server.max_batch = 4;
    cfg.scout.prefill_chunk = arm.prefill_chunk;
    let pool = EnginePool::start(cfg).expect("pool start");

    // Two streaming decodes saturate the batch tile (test-tiny B = 2).
    let decodes: Vec<StreamHandle> = (0..2)
        .map(|i| pool.submit(Submission::new(prompt(16, i), decode_tokens).streaming()))
        .collect();
    for h in &decodes {
        first_token(h);
    }

    // The interfering long admissions (measurement starts at submit).
    // Two back-to-back so the inline arm's whole-prompt stalls are a
    // robust fraction of the sampled gaps, not a single outlier.
    let t_interfere = Instant::now();
    let prefills: Vec<StreamHandle> = if arm.with_prefill {
        (0..2).map(|i| pool.submit(Submission::new(prompt(prefill_len, 9 + i), 1))).collect()
    } else {
        Vec::new()
    };

    let mut gaps_us: Vec<f64> = Vec::new();
    std::thread::scope(|s| {
        // Move each handle into its collector (mpsc receivers are !Sync).
        let stamps: Vec<_> = decodes
            .into_iter()
            .map(|h| s.spawn(move || collect_token_times(&h)))
            .collect();
        for j in stamps {
            let times = j.join().expect("collector thread");
            // Gaps entirely after the interfering submission (baseline
            // uses the same cutoff so the arms sample the same regime).
            for w in times.windows(2) {
                if w[0] >= t_interfere {
                    gaps_us.push(w[1].duration_since(w[0]).as_secs_f64() * 1e6);
                }
            }
        }
    });
    for h in prefills {
        h.wait().expect("interfering admission completed");
    }
    pool.shutdown().expect("shutdown");

    gaps_us.sort_by(|a, b| a.partial_cmp(b).unwrap());
    ArmResult {
        name: arm.name,
        gaps: gaps_us.len(),
        p50_us: percentile(&gaps_us, 0.5),
        p99_us: percentile(&gaps_us, 0.99),
    }
}

fn main() {
    let quick = smoke() || std::env::args().any(|a| a == "--quick");
    println!("prefill_interference — decode inter-token latency under a long admission");
    let (decode_tokens, prefill_len) = if quick { (16, 48) } else { (40, 200) };

    let arms = [
        Arm {
            name: "baseline",
            replicas: 1,
            roles: vec![],
            prefill_chunk: 2,
            with_prefill: false,
        },
        Arm {
            name: "inline",
            replicas: 1,
            roles: vec![],
            prefill_chunk: 1 << 30,
            with_prefill: true,
        },
        Arm {
            name: "chunked",
            replicas: 1,
            roles: vec![],
            prefill_chunk: 2,
            with_prefill: true,
        },
        Arm {
            name: "disagg",
            replicas: 3,
            roles: vec![ReplicaRole::Prefill, ReplicaRole::Decode, ReplicaRole::Decode],
            prefill_chunk: 2,
            with_prefill: true,
        },
    ];

    let mut results = Vec::new();
    for arm in &arms {
        let r = run_arm(arm, decode_tokens, prefill_len);
        println!(
            "{:<10} gaps {:>4}  inter-token p50 {:>9.1} us  p99 {:>9.1} us",
            r.name, r.gaps, r.p50_us, r.p99_us
        );
        results.push(r);
    }
    let p99_of = |name: &str| {
        results.iter().find(|r| r.name == name).map(|r| r.p99_us).unwrap_or(0.0)
    };
    let baseline = p99_of("baseline").max(1.0);

    let rows: Vec<Json> = results
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("arm", Json::str(r.name)),
                ("gaps", Json::num(r.gaps as f64)),
                ("inter_token_p50_us", Json::num(r.p50_us)),
                ("inter_token_p99_us", Json::num(r.p99_us)),
                ("p99_vs_baseline", Json::num(r.p99_us / baseline)),
            ])
        })
        .collect();
    let json = Json::obj(vec![
        ("bench", Json::str("prefill_interference")),
        ("quick", Json::Bool(quick)),
        ("decode_tokens", Json::num(decode_tokens as f64)),
        ("prefill_len", Json::num(prefill_len as f64)),
        ("rows", Json::Arr(rows)),
    ]);
    let path = std::env::var("SCOUT_BENCH_PREFILL_JSON")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| {
            std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_prefill.json")
        });
    std::fs::write(&path, json.to_string()).expect("write bench json");
    println!("wrote prefill interference rows to {}", path.display());

    if quick {
        println!("quick/smoke mode: skipping interference assertions");
        return;
    }
    let (inline, chunked, disagg) = (p99_of("inline"), p99_of("chunked"), p99_of("disagg"));
    println!(
        "p99 vs baseline: inline {:.2}x, chunked {:.2}x, disagg {:.2}x",
        inline / baseline,
        chunked / baseline,
        disagg / baseline
    );
    assert!(
        chunked <= 2.0 * baseline,
        "chunked prefill must keep decode p99 within 2x of the no-prefill baseline \
         ({chunked:.1}us vs {baseline:.1}us)"
    );
    assert!(
        disagg <= 2.0 * baseline,
        "disaggregated prefill must keep decode p99 within 2x of the no-prefill baseline \
         ({disagg:.1}us vs {baseline:.1}us)"
    );
    assert!(
        inline > 2.0 * baseline,
        "inline whole-prompt prefill should measurably blow decode p99 \
         ({inline:.1}us vs {baseline:.1}us) — if this fails, the interference scenario \
         is too small to matter on this host"
    );
}
