//! Fig. 3 — GPU idle fraction of HGCA and InfiniGen (batch 40, 32k ctx).
//!
//! Paper: InfiniGen idles 61% (I/O bound), HGCA 57% (CPU bound);
//! ScoutAttention is shown in Fig. 11 at 6%. The schedules are produced
//! by the per-method pipeline models and priced under the device model.

use scoutattention::config::Method;
use scoutattention::sim::pipeline::{MethodSim, SynthWorkload};
use scoutattention::sim::timing::DeviceModel;

fn main() {
    let w = SynthWorkload::paper_default(32768, 40);
    println!("Fig 3 — GPU utilization at batch 40, 32k context");
    println!("{:<15} {:>8} {:>8} {:>10}", "method", "idle%", "paper", "busy%");
    let paper = [("InfiniGen", Method::Infinigen, 61.0), ("HGCA", Method::Hgca, 57.0),
                 ("ScoutAttention", Method::Scout, 6.0)];
    for (name, m, expect) in paper {
        let mut sim = MethodSim::new(m, DeviceModel::default());
        if m != Method::Scout {
            sim.periodic_recall = false;
        }
        let r = sim.run(&w);
        let idle = r.idle_fraction() * 100.0;
        println!("{name:<15} {idle:>7.1}% {expect:>7.0}% {:>9.1}%", 100.0 - idle);
        assert!((idle - expect).abs() < 12.0, "{name}: {idle} vs paper {expect}");
    }
}
