//! Fig. 11 — end-to-end decode latency breakdown per method.
//!
//! Paper: idle = 61% (InfiniGen, I/O), 57% (HGCA, CPU), 6% (Scout);
//! the §3.3 anchor (attention ~300us vs ~900us full layer at the 4k
//! budget) is printed alongside.

use scoutattention::config::Method;
use scoutattention::metrics::Phase;
use scoutattention::sim::pipeline::{MethodSim, SynthWorkload};
use scoutattention::sim::timing::DeviceModel;

fn main() {
    let m = DeviceModel::default();
    // §3.3 anchor
    let kv = m.kv_layer_bytes(4096) * 40.0;
    let attn = m.gpu_attn_us(kv);
    println!(
        "anchor (batch 40, 4k budget): attention {:.0} us, full layer {:.0} us ({:.1}x window)\n",
        attn, attn + m.layer_other_us, (attn + m.layer_other_us) / attn
    );
    println!("Fig 11 — latency breakdown (% of end-to-end decode time)");
    println!("{:<15} {:>10} {:>14} {:>8}", "method", "attention", "other-compute", "idle");
    let w = SynthWorkload::paper_default(32768, 40);
    for meth in [Method::FullKv, Method::Infinigen, Method::Hgca, Method::Scout] {
        let mut sim = MethodSim::new(meth, m.clone());
        if meth != Method::Scout {
            sim.periodic_recall = false;
        }
        let r = sim.run(&w);
        let t = r.breakdown.total_us();
        println!(
            "{:<15} {:>9.1}% {:>13.1}% {:>7.1}%",
            meth.label(),
            r.breakdown.get(Phase::GpuAttention) / t * 100.0,
            (r.breakdown.get(Phase::GpuOther) + r.breakdown.get(Phase::Scheduler)) / t * 100.0,
            r.idle_fraction() * 100.0,
        );
    }
    println!("\npaper idle: InfiniGen 61%, HGCA 57%, Scout 6%");
}
