//! Fig. 12 — ablation: PC (layer-ahead pre-computation) and PR (periodic
//! recall). Paper: +PC gives 1.39x over the no-overlap base; +PR a
//! further 1.20x by cutting the CPU compute load.

use scoutattention::config::Method;
use scoutattention::sim::pipeline::{MethodSim, SynthWorkload};
use scoutattention::sim::timing::DeviceModel;

fn main() {
    let w = SynthWorkload::paper_default(32768, 40);
    println!("Fig 12 — ScoutAttention ablation (32k ctx, batch 40)");
    println!("{:<18} {:>12} {:>10} {:>8}", "arm", "tok/s", "vs prev", "idle%");
    let mut prev = 0.0;
    let mut speedups = Vec::new();
    for (name, pc, pr) in [
        ("base (-PC -PR)", false, false),
        ("+PC", true, false),
        ("+PC +PR", true, true),
    ] {
        let mut sim = MethodSim::new(Method::Scout, DeviceModel::default());
        sim.layer_ahead = pc;
        sim.periodic_recall = pr;
        let r = sim.run(&w);
        let tps = r.throughput_tps();
        let ratio = if prev > 0.0 { tps / prev } else { 1.0 };
        println!("{name:<18} {tps:>12.1} {ratio:>9.2}x {:>7.1}%", r.idle_fraction() * 100.0);
        if prev > 0.0 {
            speedups.push(ratio);
        }
        prev = tps;
    }
    println!("\npaper: +PC 1.39x, +PR 1.20x");
    assert!(speedups.iter().all(|&s| s > 1.05), "each arm must help: {speedups:?}");
}
