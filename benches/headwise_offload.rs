//! Head-wise offload granularity: recall traffic vs accuracy.
//!
//! The HeadInfer-style claim behind `scout.head_groups`: splitting the
//! offload machinery (digest scoring, resident budget, staged recall)
//! per KV head group shrinks the asynchronous recall traffic — a block
//! that only one group's query ranks highly is fetched as a
//! *group-block* (`block_bytes / head_groups`) instead of dragging
//! every head's rows across PCIe, and groups the heavy-hitter
//! classifier pins fully resident stop generating recall churn
//! entirely — at no meaningful accuracy cost.
//!
//! Arms sweep `head_groups` in {1, 4, n_kv_heads} on the test-tiny
//! stack (4 does not divide test-tiny's 2 KV heads, so that arm
//! exercises — and reports — the effective-group clamp back to 1).
//! Recall is pinned to a fixed 1-step interval so re-ranking churn is
//! maximal, and the per-step staged-recall bytes are averaged over the
//! steady-state window (past the warm-up steps in which grouped arms
//! pay the one-time pin-fill). Accuracy is token agreement with the
//! FullKV oracle on the identical stream.
//!
//! Writes BENCH_headwise.json (rows: requested/effective groups, recall
//! bytes/step, decode tok/s, agreement, classifier counts). Full runs
//! assert the acceptance contract: strictly lower steady-state recall
//! bytes/step at `head_groups = n_kv_heads` than at 1, with agreement
//! within 2.4% of the per-layer arm. Under `--quick` / SCOUT_BENCH_SMOKE
//! the arms shrink to a path-coverage smoke and assertions are skipped.

use scoutattention::config::{Method, RecallPolicy, RunConfig};
use scoutattention::coordinator::RequestSpec;
use scoutattention::harness::{self, Stack};
use scoutattention::util::bench::smoke;
use scoutattention::util::Json;

fn prompt(len: usize, salt: u32) -> Vec<u32> {
    (0..len as u32).map(|i| 1 + (i * 13 + salt * 5) % 255).collect()
}

struct ArmResult {
    requested_groups: usize,
    effective_groups: usize,
    steps: usize,
    recall_bytes_per_step: f64,
    decode_tps: f64,
    agreement: f64,
    pinned_obs: usize,
    offloaded_obs: usize,
}

fn run_arm(
    base: &RunConfig,
    head_groups: usize,
    reqs: &[RequestSpec],
    warmup_steps: usize,
    oracle: &harness::ServingRun,
) -> ArmResult {
    let mut cfg = base.clone();
    cfg.scout.head_groups = head_groups;
    let stack = Stack::load(&cfg).expect("load stack");
    let spec = &stack.gpu.spec;
    let run = harness::run_method(&stack, Method::Scout, reqs.to_vec(), 10_000, None)
        .expect("scout run");

    let eff = run.stats.iter().map(|s| s.head_groups.max(1)).max().unwrap_or(1);
    let block_bytes = (2 * spec.block_size * spec.n_kv_heads * spec.head_dim * 4) as f64;
    let unit_bytes = block_bytes / eff as f64;
    let steady = &run.stats[warmup_steps.min(run.stats.len())..];
    let staged_units: usize = steady.iter().map(|s| s.recall_staged_blocks()).sum();
    let recall_bytes_per_step = if steady.is_empty() {
        0.0
    } else {
        staged_units as f64 * unit_bytes / steady.len() as f64
    };
    ArmResult {
        requested_groups: head_groups,
        effective_groups: eff,
        steps: run.stats.len(),
        recall_bytes_per_step,
        decode_tps: run.wall_throughput_tps(),
        agreement: harness::token_agreement(&run, oracle),
        pinned_obs: run.stats.iter().map(|s| s.pinned_groups).sum(),
        offloaded_obs: run.stats.iter().map(|s| s.offloaded_groups).sum(),
    }
}

fn main() {
    let quick = smoke() || std::env::args().any(|a| a == "--quick");
    println!("headwise_offload — staged recall bytes/step vs head-group granularity");

    let mut cfg = RunConfig::for_preset("test-tiny");
    // Fixed 1-step recall: every step re-ranks and stages, so the arms
    // are compared at maximal recall churn rather than at whatever
    // cadence the profiled policy happens to pick.
    cfg.scout.recall = RecallPolicy::Fixed { interval: 1 };
    let stack = Stack::load(&cfg).expect("load base stack");
    let spec = stack.gpu.spec.clone();
    let bs = spec.block_size;

    let (n_reqs, prompt_blocks, new_tokens, warmup_steps) =
        if quick { (2, 4, 8, 0) } else { (4, 8, 96, 24) };
    let reqs: Vec<RequestSpec> = (0..n_reqs as u64)
        .map(|i| RequestSpec::new(i, prompt(prompt_blocks * bs, 11 + i as u32), new_tokens))
        .collect();
    let oracle = harness::run_method(&stack, Method::FullKv, reqs.clone(), 10_000, None)
        .expect("fullkv oracle");

    let sweep = [1usize, 4, spec.n_kv_heads];
    let mut results: Vec<ArmResult> = Vec::new();
    println!(
        "{:>9} {:>9} {:>7} {:>18} {:>12} {:>8} {:>8} {:>10}",
        "groups", "effective", "steps", "recall B/step", "decode tok/s", "agree%", "pinned",
        "offloaded"
    );
    for g in sweep {
        let r = run_arm(&cfg, g, &reqs, warmup_steps, &oracle);
        println!(
            "{:>9} {:>9} {:>7} {:>18.1} {:>12.1} {:>7.1}% {:>8} {:>10}",
            r.requested_groups,
            r.effective_groups,
            r.steps,
            r.recall_bytes_per_step,
            r.decode_tps,
            r.agreement * 100.0,
            r.pinned_obs,
            r.offloaded_obs
        );
        results.push(r);
    }

    let rows: Vec<Json> = results
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("head_groups", Json::num(r.requested_groups as f64)),
                ("effective_groups", Json::num(r.effective_groups as f64)),
                ("steps", Json::num(r.steps as f64)),
                ("recall_bytes_per_step", Json::num(r.recall_bytes_per_step)),
                ("decode_tps", Json::num(r.decode_tps)),
                ("agreement", Json::num(r.agreement)),
                ("pinned_group_obs", Json::num(r.pinned_obs as f64)),
                ("offloaded_group_obs", Json::num(r.offloaded_obs as f64)),
            ])
        })
        .collect();
    let json = Json::obj(vec![
        ("bench", Json::str("headwise_offload")),
        ("quick", Json::Bool(quick)),
        ("preset", Json::str("test-tiny")),
        ("kv_heads", Json::num(spec.n_kv_heads as f64)),
        ("warmup_steps", Json::num(warmup_steps as f64)),
        ("rows", Json::Arr(rows)),
    ]);
    let path = std::env::var("SCOUT_BENCH_HEADWISE_JSON")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| {
            std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_headwise.json")
        });
    std::fs::write(&path, json.to_string()).expect("write bench json");
    println!("wrote head-wise offload rows to {}", path.display());

    // The non-divisor arm must report the clamp, never a mis-sliced run.
    for r in &results {
        if spec.n_kv_heads % r.requested_groups != 0 {
            assert_eq!(
                r.effective_groups, 1,
                "non-divisor head_groups={} must clamp to 1",
                r.requested_groups
            );
        }
    }

    if quick {
        println!("quick/smoke mode: skipping recall-traffic assertions");
        return;
    }
    let base = &results[0];
    let headwise = results
        .iter()
        .find(|r| r.effective_groups == spec.n_kv_heads)
        .expect("head_groups = n_kv_heads arm");
    println!(
        "steady-state recall bytes/step: per-layer {:.1}, head-wise {:.1} ({:.2}x)",
        base.recall_bytes_per_step,
        headwise.recall_bytes_per_step,
        headwise.recall_bytes_per_step / base.recall_bytes_per_step.max(1e-9)
    );
    assert!(
        base.recall_bytes_per_step > 0.0,
        "per-layer arm staged no recall traffic — the comparison is vacuous \
         (recall interval or workload too short)"
    );
    assert!(
        headwise.recall_bytes_per_step < base.recall_bytes_per_step,
        "head-wise offload must strictly reduce steady-state recall bytes/step \
         ({:.1} vs {:.1})",
        headwise.recall_bytes_per_step,
        base.recall_bytes_per_step
    );
    assert!(
        headwise.agreement >= base.agreement - 0.024,
        "head-wise agreement {:.3} fell more than 2.4% below per-layer {:.3} — \
         traffic saved by losing accuracy doesn't count",
        headwise.agreement,
        base.agreement
    );
}
