//! Fig. 9 — throughput scaling with batch size at 32k context.
//!
//! Paper: InfiniGen/HGCA scale sublinearly (1.21x / 1.31x from bs16->32,
//! pinned by I/O and CPU compute); ScoutAttention scales 1.78x (16->32)
//! and 1.48x (32->64).

use scoutattention::config::Method;
use scoutattention::sim::pipeline::{MethodSim, SynthWorkload};
use scoutattention::sim::timing::DeviceModel;

fn run(m: Method, batch: usize) -> f64 {
    let mut sim = MethodSim::new(m, DeviceModel::default());
    if m != Method::Scout {
        sim.periodic_recall = false;
    }
    sim.run(&SynthWorkload::paper_default(32768, batch)).throughput_tps()
}

fn main() {
    println!("Fig 9 — decode throughput (tok/s) vs batch size, 32k context");
    println!("{:<12} {:>9} {:>9} {:>9} {:>11} {:>11}", "method", "bs16", "bs32", "bs64", "16->32", "32->64");
    for m in [Method::FullKv, Method::Infinigen, Method::Hgca, Method::Scout] {
        let t16 = run(m, 16);
        let t32 = run(m, 32);
        let t64 = run(m, 64);
        println!(
            "{:<12} {t16:>9.1} {t32:>9.1} {t64:>9.1} {:>10.2}x {:>10.2}x",
            m.label(), t32 / t16, t64 / t32
        );
    }
    let (s1632, s3264) = (run(Method::Scout, 32) / run(Method::Scout, 16),
                          run(Method::Scout, 64) / run(Method::Scout, 32));
    let i1632 = run(Method::Infinigen, 32) / run(Method::Infinigen, 16);
    let h1632 = run(Method::Hgca, 32) / run(Method::Hgca, 16);
    println!("\npaper: Scout 1.78x/1.48x, HGCA 1.31x, InfiniGen 1.21x (16->32)");
    assert!(s1632 > i1632 && s1632 > h1632, "scout must scale best");
    assert!(s3264 < s1632 + 0.35, "scaling should taper");
}
