//! Serving-plane scaling: aggregate decode requests/s and TTFT of the
//! engine pool as the replica count grows.
//!
//! Each arm starts a fresh pool (`max_batch = 1` per replica, so a
//! replica serves exactly one request at a time and the arm isolates
//! *replica-level* parallelism), submits a fixed request stream through
//! the router, and waits for every output. Requests/s and TTFT p50/p99
//! come from the pool's own telemetry — the same numbers `{"stats":
//! true}` serves in production.
//!
//! Writes BENCH_serve.json at the repo root (rows: replicas, requests/s,
//! ttft p50/p99 us, tokens/s). On a host with >= 4 cores the 4-replica
//! arm must deliver >= 2x the single-replica requests/s.

use scoutattention::config::RunConfig;
use scoutattention::serve::{EnginePool, StreamHandle, Submission};
use scoutattention::util::bench::smoke;
use scoutattention::util::Json;

const PROMPT_LEN: usize = 64;

fn prompt(salt: u32) -> Vec<u32> {
    (0..PROMPT_LEN as u32).map(|i| 1 + (i * 13 + salt * 5) % 255).collect()
}

struct ArmResult {
    replicas: usize,
    requests: usize,
    requests_per_s: f64,
    tokens_per_s: f64,
    ttft_p50_us: f64,
    ttft_p99_us: f64,
}

fn run_arm(replicas: usize, n_req: usize, new_tokens: usize) -> ArmResult {
    let mut cfg = RunConfig::for_preset("test-tiny");
    cfg.server.replicas = replicas;
    cfg.server.max_batch = 1; // one request per replica at a time
    cfg.server.queue_depth = n_req.max(1);
    let pool = EnginePool::start(cfg).expect("pool start");

    let t0 = std::time::Instant::now();
    let handles: Vec<StreamHandle> = (0..n_req)
        .map(|i| pool.submit(Submission::new(prompt(i as u32), new_tokens)))
        .collect();
    for h in handles {
        h.wait().expect("request completed");
    }
    let wall_s = t0.elapsed().as_secs_f64().max(1e-9);

    let stats = pool.stats();
    let ttft = stats.get("ttft_us").expect("ttft in stats");
    let out = ArmResult {
        replicas,
        requests: n_req,
        requests_per_s: n_req as f64 / wall_s,
        tokens_per_s: (n_req * new_tokens) as f64 / wall_s,
        ttft_p50_us: ttft.req_f64("p50").unwrap_or(0.0),
        ttft_p99_us: ttft.req_f64("p99").unwrap_or(0.0),
    };
    pool.shutdown().expect("pool shutdown");
    out
}

fn main() {
    println!("serve_throughput — engine-pool scaling on the interpreter backend");
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let (n_req, new_tokens, arms): (usize, usize, &[usize]) =
        if smoke() { (2, 2, &[1, 2]) } else { (12, 12, &[1, 2, 4]) };

    let mut rows = Vec::new();
    let mut by_replicas: Vec<(usize, f64)> = Vec::new();
    for &r in arms {
        let a = run_arm(r, n_req, new_tokens);
        println!(
            "{{\"bench\":\"serve_throughput\",\"replicas\":{},\"requests\":{},\
             \"requests_per_s\":{:.3},\"tokens_per_s\":{:.1},\
             \"ttft_p50_us\":{:.0},\"ttft_p99_us\":{:.0}}}",
            a.replicas, a.requests, a.requests_per_s, a.tokens_per_s, a.ttft_p50_us, a.ttft_p99_us
        );
        by_replicas.push((a.replicas, a.requests_per_s));
        rows.push(Json::obj(vec![
            ("replicas", Json::num(a.replicas as f64)),
            ("requests", Json::num(a.requests as f64)),
            ("requests_per_s", Json::num(a.requests_per_s)),
            ("tokens_per_s", Json::num(a.tokens_per_s)),
            ("ttft_p50_us", Json::num(a.ttft_p50_us)),
            ("ttft_p99_us", Json::num(a.ttft_p99_us)),
        ]));
    }

    let json = Json::obj(vec![
        ("bench", Json::str("serve_throughput")),
        ("smoke", Json::Bool(smoke())),
        ("cores", Json::num(cores as f64)),
        ("rows", Json::Arr(rows)),
    ]);
    let path = std::env::var("SCOUT_BENCH_SERVE_JSON")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| {
            std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_serve.json")
        });
    std::fs::write(&path, json.to_string()).expect("write bench json");
    println!("wrote serve scaling rows to {}", path.display());

    if smoke() {
        println!("smoke mode: skipping the scaling assertion (n=1 timings)");
        return;
    }
    let rps = |r: usize| by_replicas.iter().find(|(n, _)| *n == r).map(|(_, v)| *v);
    if let (Some(r1), Some(r4)) = (rps(1), rps(4)) {
        println!("replicas 1 -> 4: {r1:.2} -> {r4:.2} req/s ({:.2}x)", r4 / r1);
        if cores >= 4 {
            assert!(
                r4 >= 2.0 * r1,
                "4 replicas must deliver >= 2x the single-replica requests/s \
                 on a >=4-core host: {r4:.2} vs {r1:.2}"
            );
        } else {
            println!("only {cores} cores: scaling assertion skipped");
        }
    }
}
