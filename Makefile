# ScoutAttention build entry points.
#
# The rust workspace is self-contained: `make test` needs no artifacts
# (the interpreter backend synthesizes manifests for built-in presets).
# `make artifacts` runs the python AOT step, which lowers the JAX/Pallas
# compute plane to HLO-text artifacts for the PJRT backend — it is only
# required for `--features pjrt` runs and is skipped with a message when
# the JAX toolchain is absent.

PRESETS ?= test-tiny
ARTIFACTS_DIR := artifacts

.PHONY: all build test chaos bench bench-smoke bench-baseline bench-serve bench-prefill bench-prefix bench-tier bench-headwise audit clippy fmt artifacts clean

all: build

build:
	cargo build --release

test:
	cargo test -q

# Chaos suite: deterministic fault injection (replica panics, handoff
# faults, KV-alloc failures, stalls) against a live pool. Single-threaded
# because the fault registry is process-global; SCOUT_CHAOS_QUICK shrinks
# request counts for smoke runs (unset it for the full sweep).
chaos:
	SCOUT_CHAOS_QUICK=1 cargo test --release --test chaos -- --test-threads=1

bench: build
	cargo bench

# Run every bench target once with a single measured iteration (the
# in-tree harness reads SCOUT_BENCH_SMOKE; perf assertions are skipped).
# Keeps benches compiling AND running in CI so they can't silently rot.
bench-smoke: build
	SCOUT_BENCH_SMOKE=1 cargo bench

# Record the perf baseline: full (statistical) runs of the hot-path
# kernel A/B bench and the worker-group scaling sweep, leaving
# BENCH_hotpath.json / BENCH_worker_groups.json at the repo root
# (machine-readable rows: kernel, level, size, ns/iter, GB/s). On AVX2
# hardware hotpath_micro also asserts the >= 2x matvec/attend_blocks
# kernel speedup over the scalar baseline.
bench-baseline: build
	cargo bench --bench hotpath_micro
	cargo bench --bench worker_group_scaling

# Serving-plane scaling: requests/s + TTFT p50/p99 vs replica count,
# written to BENCH_serve.json. On a >=4-core host the 4-replica arm
# asserts >= 2x the single-replica requests/s.
bench-serve: build
	cargo bench --bench serve_throughput

# Prefill interference: decode inter-token p50/p99 with a concurrent
# long admission — inline vs chunked vs disaggregated (role-split pool
# with KV handoff) — written to BENCH_prefill.json. Full runs assert
# chunked/disaggregated stay within 2x of the no-prefill baseline while
# inline does not.
bench-prefill: build
	cargo bench --bench prefill_interference

# Cross-request prefix reuse: TTFT at 0/50/90% shared-system-prompt
# traffic on the serve-20m preset, written to BENCH_prefix.json. Full
# runs assert TTFT drops monotonically with the hit rate and the
# 90%-hit arm is at most half the 0%-hit TTFT.
bench-prefix: build
	cargo bench --bench prefix_reuse

# Session-tier suspend/resume: TTFT of resuming an 8k/32k-token session
# vs. re-prefilling its full history (bench-32k preset), written to
# BENCH_tier.json. Full runs assert resume TTFT is strictly below the
# re-prefill TTFT at every history length.
bench-tier: build
	cargo bench --bench tier_resume

# Head-wise offload granularity: steady-state staged-recall bytes/step
# and decode tok/s at head_groups in {1, 4, n_kv_heads} (test-tiny),
# written to BENCH_headwise.json. Full runs assert strictly lower recall
# bytes/step at head_groups = n_kv_heads vs 1 with token agreement
# within 2.4% of the per-layer arm.
bench-headwise: build
	cargo bench --bench headwise_offload

# Concurrency-invariant lint: SAFETY comments on every unsafe, ordering
# justifications on every explicit Ordering, no lock guards held across
# blocking calls, no unwrap/expect in hot paths. Runs its seeded-bug
# self-test first so the linter itself can't silently rot. The python
# mirror (tools/audit.py) runs the same checks without a toolchain.
audit:
	cargo xtask audit --self-test
	cargo xtask audit

clippy:
	cargo clippy --workspace --all-targets -- -D warnings

fmt:
	cargo fmt --check

# AOT-lower the python compute plane (L1/L2) into HLO-text artifacts +
# manifests consumed by the PJRT backend. No-ops with a clear message
# when python/JAX is unavailable; the default interpreter backend does
# not need these files.
artifacts:
	@if python3 -c "import jax" 2>/dev/null; then \
		(cd python && python3 -m compile.aot --out-dir ../$(ARTIFACTS_DIR) \
			$(foreach p,$(PRESETS),--preset $(p))); \
		ln -sfn ../$(ARTIFACTS_DIR) rust/$(ARTIFACTS_DIR); \
	else \
		echo "make artifacts: python3/JAX toolchain not available — skipping."; \
		echo "  (The rust test suite runs on the interpreter backend and"; \
		echo "   does not need artifacts; only --features pjrt does.)"; \
	fi

clean:
	cargo clean
	rm -rf $(ARTIFACTS_DIR) rust/$(ARTIFACTS_DIR)
